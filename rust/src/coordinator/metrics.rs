//! Serving metrics: counters + latency histograms + decode throughput +
//! attention-time / pool-utilization instrumentation.

use std::time::Instant;

use crate::kvcache::cache::{ATTN_WIDTH_BUCKETS, ATTN_WIDTH_LABELS};

#[derive(Debug, Clone)]
pub struct Metrics {
    started: Instant,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub completions: usize,
    pub oom_events: usize,
    pub ttft_ms: Histogram,
    /// time between consecutive tokens of the same sequence (ms): one
    /// sample per decode token, measured on the engine clock.  The
    /// serving-latency metric chunked prefill exists to protect — an
    /// inline whole-prompt prefill shows up here as a p99 spike
    /// (DESIGN.md §Scheduler)
    pub tbt_ms: Histogram,
    pub total_ms: Histogram,
    pub step_us: Histogram,
    /// per-step fraction of the `--step-tokens` budget actually planned
    /// (decode + chunk tokens) — recorded only in chunked mode; values
    /// over 1.0 mean decode lanes alone exceeded the budget
    pub budget_util: Histogram,
    /// per-step wall time of the decode attention fan-out (append+attend
    /// summed over layers), in microseconds
    pub attn_us: Histogram,
    /// accumulated attend kernel time split by block bit width
    /// (`attn_width_bucket` order: 1/2/3/4/8/16-bit + the fp window) —
    /// where decode attention time actually goes under a mixed plan
    pub attn_ns_by_width: [u64; ATTN_WIDTH_BUCKETS],
    /// per-step worker-pool utilization of the decode attention fan-out:
    /// `busy_time / (threads * attention_wall_time)`, in `[0, 1]`.
    /// Only recorded when the engine runs with a pool of >1 threads.
    pub pool_util: Histogram,
    pub peak_kv_bytes: usize,
    /// pages the pressure controller requantized down the bit ladder
    /// (paged mode only — DESIGN.md §Memory-Manager)
    pub pages_requantized: usize,
    /// sequences preempted back to the batcher queue after downshift was
    /// exhausted (paged mode; monolithic evictions count as `oom_events`)
    pub preemptions: usize,
    /// admissions whose prompt adopted shared prefix pages from the
    /// pool's prefix index (`--prefix-cache` — DESIGN.md §Prefix-Sharing)
    pub prefix_hits: usize,
    /// prompt tokens covered by adopted shared pages across all hits
    /// (their quantized pages were mapped, not re-encoded)
    pub prefix_tokens_reused: usize,
    /// copy-on-write splits: downshifts that landed on a shared page and
    /// gave the downshifting sequence a private copy instead of mutating
    /// the shared bytes (mirrors `PoolStats::cow_splits`)
    pub cow_splits: usize,
    /// requests retired early by a client cancel frame or disconnect
    /// (`Engine::cancel` — DESIGN.md §Serving-Protocol); not counted in
    /// `completions`
    pub cancellations: usize,
    /// requests retired by the engine's deadline sweep (`deadline_ms`
    /// exceeded while waiting or mid-decode); not counted in `completions`
    pub deadline_hits: usize,
    /// sealed cold pages written to the disk spill tier by the pressure
    /// ladder's spill rung (`--spill-dir` — DESIGN.md §Spill-Tier)
    pub pages_spilled: usize,
    /// spilled pages faulted back into memory before an attend touched
    /// them (the spill tier's read path)
    pub spill_faults: usize,
    /// finished conversations whose KV pages parked under a session key
    /// instead of freeing (`"session"` — DESIGN.md §Serving-Protocol)
    pub sessions_parked: usize,
    /// admissions that resumed a parked session's pages
    pub sessions_resumed: usize,
    /// prompt tokens covered by resumed session pages across all resumes
    /// (their quantized pages were adopted, not re-encoded)
    pub resume_tokens_reused: usize,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics { started: Instant::now(), prefill_tokens: 0, decode_tokens: 0,
                  completions: 0, oom_events: 0, ttft_ms: Histogram::default(),
                  tbt_ms: Histogram::default(), total_ms: Histogram::default(),
                  step_us: Histogram::default(), budget_util: Histogram::default(),
                  attn_us: Histogram::default(),
                  attn_ns_by_width: [0; ATTN_WIDTH_BUCKETS],
                  pool_util: Histogram::default(),
                  peak_kv_bytes: 0, pages_requantized: 0, preemptions: 0,
                  prefix_hits: 0, prefix_tokens_reused: 0, cow_splits: 0,
                  cancellations: 0, deadline_hits: 0, pages_spilled: 0,
                  spill_faults: 0, sessions_parked: 0, sessions_resumed: 0,
                  resume_tokens_reused: 0 }
    }
}

impl Metrics {
    /// Wall-clock seconds since the engine was created (includes idle
    /// time; use [`Metrics::throughput`] for serving rate).
    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Decode throughput in tokens/second.
    ///
    /// **Definition:** `decode_tokens / Σ step_us` — tokens produced per
    /// second of *engine step wall time* (the accumulated duration of
    /// [`Engine::step`](crate::coordinator::Engine::step) calls), not per
    /// second since `Engine::new`.  An engine that sat idle in the queue
    /// loop before or between requests is therefore not under-reported.
    /// Returns 0.0 before the first step completes.
    pub fn throughput(&self) -> f64 {
        let decode_secs = self.step_us.sum() / 1e6;
        if decode_secs <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / decode_secs
    }

    pub fn now_ns(&self) -> u64 {
        self.started.elapsed().as_nanos() as u64
    }

    /// Fold another registry into this one — the router's cross-replica
    /// stats aggregation (DESIGN.md §Replication).  Counters sum,
    /// histograms concatenate their samples (quantiles over the union),
    /// and `peak_kv_bytes` takes the max: replica peaks are concurrent
    /// highwater marks of *separate* pools, so the fleet-wide figure is
    /// conservative (true simultaneous usage may be lower).  `started` /
    /// `elapsed_s` keep the receiver's clock.
    pub fn merge(&mut self, other: &Metrics) {
        self.prefill_tokens += other.prefill_tokens;
        self.decode_tokens += other.decode_tokens;
        self.completions += other.completions;
        self.oom_events += other.oom_events;
        self.ttft_ms.merge(&other.ttft_ms);
        self.tbt_ms.merge(&other.tbt_ms);
        self.total_ms.merge(&other.total_ms);
        self.step_us.merge(&other.step_us);
        self.budget_util.merge(&other.budget_util);
        self.attn_us.merge(&other.attn_us);
        for (a, b) in self.attn_ns_by_width.iter_mut().zip(&other.attn_ns_by_width) {
            *a += b;
        }
        self.pool_util.merge(&other.pool_util);
        self.peak_kv_bytes = self.peak_kv_bytes.max(other.peak_kv_bytes);
        self.pages_requantized += other.pages_requantized;
        self.preemptions += other.preemptions;
        self.prefix_hits += other.prefix_hits;
        self.prefix_tokens_reused += other.prefix_tokens_reused;
        self.cow_splits += other.cow_splits;
        self.cancellations += other.cancellations;
        self.deadline_hits += other.deadline_hits;
        self.pages_spilled += other.pages_spilled;
        self.spill_faults += other.spill_faults;
        self.sessions_parked += other.sessions_parked;
        self.sessions_resumed += other.sessions_resumed;
        self.resume_tokens_reused += other.resume_tokens_reused;
    }

    pub fn report(&mut self) -> String {
        let util = if self.pool_util.is_empty() {
            String::new()
        } else {
            format!(" | pool util {:.0}%", self.pool_util.mean() * 100.0)
        };
        let pressure = if self.pages_requantized == 0 && self.preemptions == 0 {
            String::new()
        } else {
            format!(" | requant {} pages | preempt {}",
                    self.pages_requantized, self.preemptions)
        };
        let prefix = if self.prefix_hits == 0 && self.cow_splits == 0 {
            String::new()
        } else {
            format!(" | prefix hits {} ({} tok reused) | cow {}",
                    self.prefix_hits, self.prefix_tokens_reused, self.cow_splits)
        };
        let tbt = if self.tbt_ms.is_empty() {
            String::new()
        } else {
            format!(" | tbt p50 {:.1} ms p99 {:.1} ms",
                    self.tbt_ms.quantile(0.5), self.tbt_ms.quantile(0.99))
        };
        let budget = if self.budget_util.is_empty() {
            String::new()
        } else {
            format!(" | step budget util {:.0}%", self.budget_util.mean() * 100.0)
        };
        let early = if self.cancellations == 0 && self.deadline_hits == 0 {
            String::new()
        } else {
            format!(" | cancelled {} | deadline {}",
                    self.cancellations, self.deadline_hits)
        };
        let spill = if self.pages_spilled == 0 && self.spill_faults == 0 {
            String::new()
        } else {
            format!(" | spilled {} pages ({} faults)",
                    self.pages_spilled, self.spill_faults)
        };
        let session = if self.sessions_parked == 0 && self.sessions_resumed == 0 {
            String::new()
        } else {
            format!(" | sessions parked {} resumed {} ({} tok reused)",
                    self.sessions_parked, self.sessions_resumed,
                    self.resume_tokens_reused)
        };
        let by_width = {
            let tot: u64 = self.attn_ns_by_width.iter().sum();
            if tot == 0 {
                String::new()
            } else {
                let shares: Vec<String> = self.attn_ns_by_width.iter()
                    .zip(ATTN_WIDTH_LABELS)
                    .filter(|(&ns, _)| ns > 0)
                    .map(|(&ns, label)| {
                        format!("{label} {:.0}%", ns as f64 / tot as f64 * 100.0)
                    })
                    .collect();
                format!(" | attn by width: {}", shares.join(" "))
            }
        };
        format!(
            "tokens: prefill {} decode {} | completions {} | throughput {:.1} tok/s | \
             ttft p50 {:.1} ms p95 {:.1} ms{} | e2e p50 {:.1} ms | step p50 {:.0} µs | \
             attn p50 {:.0} µs{}{}{} | peak kv {:.2} MiB | oom {}{}{}{}{}{}",
            self.prefill_tokens, self.decode_tokens, self.completions,
            self.throughput(), self.ttft_ms.quantile(0.5), self.ttft_ms.quantile(0.95),
            tbt, self.total_ms.quantile(0.5), self.step_us.quantile(0.5),
            self.attn_us.quantile(0.5), by_width, util, budget,
            self.peak_kv_bytes as f64 / (1 << 20) as f64, self.oom_events, pressure,
            prefix, early, spill, session)
    }
}

/// Simple exact histogram (stores samples; fine at serving-bench scale).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Sum of all recorded samples.
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Concatenate another histogram's samples (cross-replica merge):
    /// quantiles afterwards are over the union, not an average of
    /// per-replica quantiles.
    pub fn merge(&mut self, other: &Histogram) {
        if other.samples.is_empty() {
            return;
        }
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let idx = ((self.samples.len() - 1) as f64 * q).round() as usize;
        self.samples[idx]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(1.0), 100.0);
        assert!((h.quantile(0.5) - 50.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.sum() - 5050.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_uses_decode_wall_time_not_engine_age() {
        let mut m = Metrics::default();
        // engine idle before the first request must not dilute throughput:
        // 100 tokens over 2 accumulated step-seconds = 50 tok/s regardless
        // of when the engine was created
        m.decode_tokens = 100;
        m.step_us.record(1_500_000.0);
        m.step_us.record(500_000.0);
        assert!((m.throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn report_includes_prefix_line_only_when_active() {
        let mut m = Metrics::default();
        assert!(!m.report().contains("prefix hits"));
        m.prefix_hits = 2;
        m.prefix_tokens_reused = 128;
        m.cow_splits = 1;
        let r = m.report();
        assert!(r.contains("prefix hits 2 (128 tok reused)"), "{r}");
        assert!(r.contains("cow 1"), "{r}");
    }

    #[test]
    fn report_includes_tbt_and_budget_lines_only_when_active() {
        let mut m = Metrics::default();
        let r = m.report();
        assert!(!r.contains("tbt p50"), "{r}");
        assert!(!r.contains("step budget util"), "{r}");
        m.tbt_ms.record(4.0);
        m.tbt_ms.record(4.0);
        m.tbt_ms.record(8.0);
        m.budget_util.record(0.5);
        m.budget_util.record(1.0);
        let r = m.report();
        assert!(r.contains("tbt p50 4.0 ms p99 8.0 ms"), "{r}");
        assert!(r.contains("step budget util 75%"), "{r}");
    }

    #[test]
    fn report_includes_early_retirements_only_when_active() {
        let mut m = Metrics::default();
        assert!(!m.report().contains("cancelled"));
        m.cancellations = 3;
        m.deadline_hits = 1;
        let r = m.report();
        assert!(r.contains("cancelled 3"), "{r}");
        assert!(r.contains("deadline 1"), "{r}");
    }

    #[test]
    fn report_includes_spill_and_session_lines_only_when_active() {
        let mut m = Metrics::default();
        let r = m.report();
        assert!(!r.contains("spilled"), "{r}");
        assert!(!r.contains("sessions"), "{r}");
        m.pages_spilled = 4;
        m.spill_faults = 3;
        m.sessions_parked = 2;
        m.sessions_resumed = 1;
        m.resume_tokens_reused = 128;
        let r = m.report();
        assert!(r.contains("spilled 4 pages (3 faults)"), "{r}");
        assert!(r.contains("sessions parked 2 resumed 1 (128 tok reused)"), "{r}");
    }

    #[test]
    fn report_includes_width_breakdown_only_when_active() {
        let mut m = Metrics::default();
        assert!(!m.report().contains("attn by width"), "silent until sampled");
        m.attn_ns_by_width[1] = 750; // 2-bit
        m.attn_ns_by_width[6] = 250; // fp window
        let r = m.report();
        assert!(r.contains("attn by width: 2b 75% fp 25%"), "{r}");
        assert!(!r.contains("4b"), "empty buckets stay out of the report: {r}");
    }

    #[test]
    fn merge_sums_width_breakdown_elementwise() {
        let mut a = Metrics::default();
        a.attn_ns_by_width[1] = 100;
        let mut b = Metrics::default();
        b.attn_ns_by_width[1] = 50;
        b.attn_ns_by_width[3] = 25;
        a.merge(&b);
        assert_eq!(a.attn_ns_by_width[1], 150);
        assert_eq!(a.attn_ns_by_width[3], 25);
    }

    #[test]
    fn merge_sums_counters_unions_histograms_maxes_peak() {
        let mut a = Metrics::default();
        a.decode_tokens = 10;
        a.completions = 2;
        a.peak_kv_bytes = 100;
        a.pages_spilled = 1;
        a.ttft_ms.record(1.0);
        a.ttft_ms.record(2.0);
        let mut b = Metrics::default();
        b.decode_tokens = 5;
        b.completions = 1;
        b.peak_kv_bytes = 300;
        b.sessions_resumed = 2;
        b.ttft_ms.record(10.0);
        a.merge(&b);
        assert_eq!(a.decode_tokens, 15);
        assert_eq!(a.completions, 3);
        assert_eq!(a.peak_kv_bytes, 300, "peaks max, not sum");
        assert_eq!((a.pages_spilled, a.sessions_resumed), (1, 2));
        assert_eq!(a.ttft_ms.len(), 3);
        assert_eq!(a.ttft_ms.quantile(1.0), 10.0, "quantiles over the union");
    }

    #[test]
    fn throughput_zero_before_first_step() {
        let mut m = Metrics::default();
        m.decode_tokens = 5; // hypothetical; no steps recorded yet
        assert_eq!(m.throughput(), 0.0);
        let _ = m.report();
    }
}
