//! L3 serving coordinator: request router, continuous batcher,
//! prefill/decode scheduling, engine loop, metrics, TCP server.
//!
//! The paper is a serving-side contribution, so the coordinator follows
//! the vLLM-router shape: requests enter a FIFO, the batcher admits them
//! into the running batch under a (simulated-HBM) memory budget computed
//! from the cache policy's modeled bytes/token (with a bounded admission
//! lookahead against head-of-line blocking), and the engine interleaves
//! prefill with one batched decode step per iteration.  Under memory
//! pressure the paged pool first requantizes old pages down the bit
//! ladder and then preempts the youngest request (monolithic mode keeps
//! the plain evict-youngest-on-OOM policy) — DESIGN.md §Memory-Manager.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod server;

pub use batcher::Batcher;
pub use engine::{estimate_bytes_per_token, Engine, EngineCfg};
pub use metrics::{Histogram, Metrics};
pub use request::{ActiveRequest, Completion, Request, RequestId};
