//! L3 serving coordinator: request router, continuous batcher,
//! iteration-level scheduler, engine loop, metrics, TCP server.
//!
//! The paper is a serving-side contribution, so the coordinator follows
//! the vLLM-router shape: requests enter a priority-banded FIFO, the
//! scheduler plans
//! each step — one decode token per running sequence first, then the
//! remaining `--step-tokens` budget as group-aligned prefill chunks and
//! fresh admissions through the batcher's bounded lookahead
//! (DESIGN.md §Scheduler) — and the engine executes the plan, charges
//! the (simulated-HBM) memory budget and retires completions.  Under
//! memory pressure the paged pool first requantizes old pages down the
//! bit ladder and then preempts the youngest request (monolithic mode
//! keeps the plain evict-youngest-on-OOM policy) —
//! DESIGN.md §Memory-Manager.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod proto;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::Batcher;
pub use engine::{estimate_bytes_per_token, Engine, EngineCfg};
pub use metrics::{Histogram, Metrics};
pub use request::{ActiveRequest, Completion, FinishReason, Lifecycle, Rejection,
                  Request, RequestId};
pub use router::{route_replica, Router};
pub use scheduler::{ChunkGrant, Scheduler, StepPlan};
pub use server::ServeCfg;
