//! Continuous batcher: the waiting-request queue (priority bands, FIFO
//! within a band — see [`Batcher::submit`]) and its admission mechanics
//! (slots, memory projections, bounded lookahead).
//!
//! Waiting requests join the running batch whenever (a) a batch slot is
//! free (`max_batch`, bounded by the largest compiled bucket) and (b) the
//! memory budget admits the request's *projected* KV footprint — prompt
//! plus max_new_tokens at the policy's bytes/token rate.  This is the
//! vLLM-style continuous batching loop, with the projection made cheap by
//! the cache's modeled bytes/token.
//!
//! Admission scans a bounded lookahead of the queue ([`ADMIT_LOOKAHEAD`])
//! so one huge projected request cannot starve small ones behind it.
//!
//! Admission *policy* — when the engine asks for the next request, and
//! how the per-step token budget gates it — lives in the iteration-level
//! scheduler (`coordinator/scheduler.rs`, DESIGN.md §Scheduler), which
//! calls [`Batcher::admit_with_reuse`] for the slot/memory/lookahead
//! mechanics here.

use std::collections::VecDeque;

use crate::kvcache::MemoryBudget;

use super::request::Request;

/// Bounded admission lookahead: [`Batcher::admit`] considers at most this
/// many waiting requests from the head of the FIFO.  A head request whose
/// projected footprint cannot currently fit no longer blocks admissible
/// smaller requests queued just behind it (head-of-line blocking), and
/// the bound keeps admission O(1) per step.
///
/// The trade-off, stated plainly: this is *not* strict FIFO anymore.  A
/// memory-blocked head is examined first every step but can be overtaken
/// repeatedly — under a sustained stream of small requests that keep
/// free memory below its projection, a large head may wait unboundedly
/// (the bound limits how deep the scheduler looks, not how long the head
/// waits; there is no aging or memory-reservation mechanism).  Requests
/// *beyond* the window cannot overtake, and among requests that fit,
/// oldest still wins.  In paged mode the engine's admission-time
/// pressure relief works in the head's favor by downshifting old pages
/// toward its projection (see `coordinator/engine.rs`).
pub const ADMIT_LOOKAHEAD: usize = 4;

pub struct Batcher {
    pub queue: VecDeque<Request>,
    pub max_batch: usize,
    /// modeled KV bytes per token per sequence for the active policy
    pub bytes_per_token: f64,
}

impl Batcher {
    pub fn new(max_batch: usize, bytes_per_token: f64) -> Self {
        Batcher { queue: VecDeque::new(), max_batch, bytes_per_token }
    }

    /// Enqueue by priority: a request lands *behind* every waiting request
    /// of equal-or-higher priority and *ahead* of strictly lower ones, so
    /// equal priorities keep FIFO order and the default priority 0 is
    /// bit-for-bit the old pure FIFO.  Preempt-restart requeues bypass
    /// this on purpose (`queue.push_front` in the engine): a preempted
    /// victim resumes at the head regardless of priority, preserving the
    /// restart-fairness the scheduler tests pin.
    pub fn submit(&mut self, req: Request) {
        let pos = self.queue.iter().position(|q| q.priority < req.priority)
            .unwrap_or(self.queue.len());
        self.queue.insert(pos, req);
    }

    /// Remove a waiting request by id (the cancellation path for requests
    /// that never reached the running batch).  `None` if `id` is not
    /// queued — already admitted, finished, or unknown.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let pos = self.queue.iter().position(|q| q.id == id)?;
        self.queue.remove(pos)
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// Projected KV bytes of a request at completion.
    pub fn projected_bytes(&self, req: &Request) -> usize {
        self.projected_suffix_bytes(req, 0)
    }

    /// Projected KV bytes of a request at completion, discounting
    /// `reused` prompt tokens whose quantized pages an admission would
    /// adopt from the prefix index instead of allocating
    /// (DESIGN.md §Prefix-Sharing) — only the *unshared* suffix is
    /// booked against the budget.
    pub fn projected_suffix_bytes(&self, req: &Request, reused: usize) -> usize {
        let tokens = req.prompt.len().saturating_sub(reused) + req.max_new_tokens;
        (tokens as f64 * self.bytes_per_token).ceil() as usize
    }

    /// Pop the next admissible request: the oldest of the first
    /// [`ADMIT_LOOKAHEAD`] waiting requests whose projected footprint
    /// fits the free budget, provided a batch slot is free.
    pub fn admit(&mut self, active: usize, budget: &MemoryBudget) -> Option<Request> {
        self.admit_with_reuse(active, budget, &|_| 0)
    }

    /// [`Batcher::admit`] with a prefix-reuse probe: `reused(req)`
    /// reports the prompt tokens whose quantized pages a prefix-cache
    /// hit would adopt (0 without the cache), so a batchful of
    /// same-system-prompt requests books the shared prefix once instead
    /// of once per member.  The engine passes a read-only pool probe;
    /// the plain [`Batcher::admit`] is the probe-less special case.
    pub fn admit_with_reuse(&mut self, active: usize, budget: &MemoryBudget,
                            reused: &dyn Fn(&Request) -> usize) -> Option<Request> {
        if active >= self.max_batch {
            return None;
        }
        let lim = self.queue.len().min(ADMIT_LOOKAHEAD);
        for i in 0..lim {
            let r = reused(&self.queue[i]);
            if self.projected_suffix_bytes(&self.queue[i], r) <= budget.free() {
                return self.queue.remove(i);
            }
        }
        None
    }

    /// Smallest projected footprint within the admission lookahead — what
    /// the pressure controller must free for admission to progress
    /// (`None` when the queue is empty).
    pub fn min_projected_in_lookahead(&self) -> Option<usize> {
        self.min_projected_in_lookahead_with(&|_| 0)
    }

    /// [`Batcher::min_projected_in_lookahead`] under the same
    /// prefix-reuse probe as [`Batcher::admit_with_reuse`].
    pub fn min_projected_in_lookahead_with(&self, reused: &dyn Fn(&Request) -> usize)
                                           -> Option<usize> {
        self.queue.iter().take(ADMIT_LOOKAHEAD)
            .map(|r| self.projected_suffix_bytes(r, reused(r)))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Sampler;

    fn req(id: u64, prompt: usize, new: usize) -> Request {
        Request { id, prompt: vec![1; prompt], max_new_tokens: new,
                  sampler: Sampler::Greedy, stop_token: None,
                  priority: 0, deadline_ms: None, submitted_ns: 0, session: None }
    }

    #[test]
    fn respects_batch_cap() {
        let mut b = Batcher::new(2, 10.0);
        b.submit(req(1, 4, 4));
        let budget = MemoryBudget::new(1_000_000, 0).unwrap();
        assert!(b.admit(2, &budget).is_none());
        assert!(b.admit(1, &budget).is_some());
    }

    #[test]
    fn respects_memory_budget() {
        let mut b = Batcher::new(8, 100.0);
        b.submit(req(1, 10, 10));       // projected 2000 bytes
        let mut budget = MemoryBudget::new(2_500, 0).unwrap();
        budget.alloc(1_000).unwrap();   // only 1500 free
        assert!(b.admit(0, &budget).is_none());
        budget.release(1_000);
        assert!(b.admit(0, &budget).is_some());
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(8, 1.0);
        b.submit(req(1, 1, 1));
        b.submit(req(2, 1, 1));
        let budget = MemoryBudget::new(1_000_000, 0).unwrap();
        assert_eq!(b.admit(0, &budget).unwrap().id, 1);
        assert_eq!(b.admit(0, &budget).unwrap().id, 2);
    }

    #[test]
    fn lookahead_skips_head_of_line_blocker() {
        let mut b = Batcher::new(8, 100.0);
        b.submit(req(1, 1_000, 1_000)); // projected 200_000: cannot fit
        b.submit(req(2, 5, 5));         // projected 1_000: fits
        let budget = MemoryBudget::new(10_000, 0).unwrap();
        assert_eq!(b.admit(0, &budget).unwrap().id, 2, "small request must not starve");
        assert!(b.admit(0, &budget).is_none(), "blocker itself still waits");
        assert_eq!(b.waiting(), 1);
        assert_eq!(b.min_projected_in_lookahead(), Some(200_000));
    }

    #[test]
    fn reuse_discount_admits_shared_prefix_request() {
        // projected 2000 bytes exclusively, but 1500 of prompt is a
        // registered prefix: only the suffix is booked, and it fits
        let mut b = Batcher::new(8, 100.0);
        b.submit(req(1, 15, 5));
        let budget = MemoryBudget::new(1_000, 0).unwrap();
        assert!(b.admit(0, &budget).is_none(), "books 2000 > 1000 without reuse");
        assert_eq!(b.min_projected_in_lookahead(), Some(2_000));
        let probe = |r: &Request| if r.id == 1 { 10 } else { 0 };
        assert_eq!(b.min_projected_in_lookahead_with(&probe), Some(1_000));
        assert_eq!(b.admit_with_reuse(0, &budget, &probe).unwrap().id, 1);
        // a reuse claim larger than the prompt saturates, never underflows
        b.submit(req(2, 4, 4));
        assert_eq!(b.projected_suffix_bytes(&b.queue[0], 100), 400);
    }

    #[test]
    fn priority_orders_queue_equal_keeps_fifo() {
        let mut b = Batcher::new(8, 1.0);
        let mut p = |id, pri| {
            let mut r = req(id, 1, 1);
            r.priority = pri;
            b.submit(r);
        };
        p(1, 0);
        p(2, 0);
        p(3, 5);  // overtakes both priority-0 entries
        p(4, 5);  // equal priority: behind 3, still ahead of 1 and 2
        p(5, -1); // below default: joins the tail
        let order: Vec<u64> = b.queue.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![3, 4, 1, 2, 5]);
    }

    #[test]
    fn remove_pops_by_id_only_when_waiting() {
        let mut b = Batcher::new(8, 1.0);
        b.submit(req(1, 1, 1));
        b.submit(req(2, 1, 1));
        assert_eq!(b.remove(2).unwrap().id, 2);
        assert!(b.remove(2).is_none(), "already removed");
        assert!(b.remove(99).is_none(), "never queued");
        assert_eq!(b.waiting(), 1);
    }

    #[test]
    fn lookahead_is_bounded() {
        let mut b = Batcher::new(8, 100.0);
        for id in 0..ADMIT_LOOKAHEAD as u64 {
            b.submit(req(id, 1_000, 1_000)); // a full window of blockers
        }
        b.submit(req(99, 1, 1)); // admissible, but beyond the window
        let budget = MemoryBudget::new(10_000, 0).unwrap();
        assert!(b.admit(0, &budget).is_none(),
                "requests beyond ADMIT_LOOKAHEAD must not be admitted");
        assert!(b.min_projected_in_lookahead().unwrap() > budget.free());
    }
}
