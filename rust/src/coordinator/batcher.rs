//! Continuous batcher: admission control for the decode batch.
//!
//! Waiting requests join the running batch whenever (a) a batch slot is
//! free (`max_batch`, bounded by the largest compiled bucket) and (b) the
//! memory budget admits the request's *projected* KV footprint — prompt
//! plus max_new_tokens at the policy's bytes/token rate.  This is the
//! vLLM-style continuous batching loop, with the projection made cheap by
//! the cache's modeled bytes/token.

use std::collections::VecDeque;

use crate::kvcache::MemoryBudget;

use super::request::Request;

pub struct Batcher {
    pub queue: VecDeque<Request>,
    pub max_batch: usize,
    /// modeled KV bytes per token per sequence for the active policy
    pub bytes_per_token: f64,
}

impl Batcher {
    pub fn new(max_batch: usize, bytes_per_token: f64) -> Self {
        Batcher { queue: VecDeque::new(), max_batch, bytes_per_token }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// Projected KV bytes of a request at completion.
    pub fn projected_bytes(&self, req: &Request) -> usize {
        ((req.prompt.len() + req.max_new_tokens) as f64 * self.bytes_per_token).ceil() as usize
    }

    /// Pop the next request if a slot is free and the budget admits it.
    pub fn admit(&mut self, active: usize, budget: &MemoryBudget) -> Option<Request> {
        if active >= self.max_batch {
            return None;
        }
        let req = self.queue.front()?;
        if self.projected_bytes(req) > budget.free() {
            return None;
        }
        self.queue.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Sampler;

    fn req(id: u64, prompt: usize, new: usize) -> Request {
        Request { id, prompt: vec![1; prompt], max_new_tokens: new,
                  sampler: Sampler::Greedy, stop_token: None, submitted_ns: 0 }
    }

    #[test]
    fn respects_batch_cap() {
        let mut b = Batcher::new(2, 10.0);
        b.submit(req(1, 4, 4));
        let budget = MemoryBudget::new(1_000_000, 0).unwrap();
        assert!(b.admit(2, &budget).is_none());
        assert!(b.admit(1, &budget).is_some());
    }

    #[test]
    fn respects_memory_budget() {
        let mut b = Batcher::new(8, 100.0);
        b.submit(req(1, 10, 10));       // projected 2000 bytes
        let mut budget = MemoryBudget::new(2_500, 0).unwrap();
        budget.alloc(1_000).unwrap();   // only 1500 free
        assert!(b.admit(0, &budget).is_none());
        budget.release(1_000);
        assert!(b.admit(0, &budget).is_some());
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(8, 1.0);
        b.submit(req(1, 1, 1));
        b.submit(req(2, 1, 1));
        let budget = MemoryBudget::new(1_000_000, 0).unwrap();
        assert_eq!(b.admit(0, &budget).unwrap().id, 1);
        assert_eq!(b.admit(0, &budget).unwrap().id, 2);
    }
}
