#!/usr/bin/env python3
"""Markdown link + DESIGN.md section + path cross-reference checker.

Three classes of rot this catches (run by .github/workflows/verify.yml
and usable locally as `python3 scripts/check_doc_links.py`):

1. Relative markdown links in README.md, DESIGN.md and docs/**/*.md that
   point at files which don't exist.
2. `DESIGN.md §<section>` references anywhere in the repo — markdown
   *and* rustdoc/source comments under rust/, examples/, python/,
   scripts/ (doc comments cite design sections by name, e.g.
   `DESIGN.md §Memory-Manager`) — that don't resolve to a
   `## §<section>` heading in DESIGN.md.
3. Repo-relative *path* citations in the same trees — rustdoc lines like
   `see rust/tests/prefix.rs` or `docs/adr/003-prefix-sharing.md`, and
   top-level doc names like `README.md` — that point at files which
   don't exist (how a renamed test or ADR would otherwise rot silently).

Exit code 0 = clean, 1 = at least one broken reference (all are listed).
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# files whose markdown links we verify
DOC_FILES = [ROOT / "README.md", ROOT / "DESIGN.md"]
DOC_FILES += sorted((ROOT / "docs").rglob("*.md"))

# trees scanned for `DESIGN.md §...` and path references
REF_TREES = ["rust/src", "rust/tests", "rust/benches", "examples", "python",
             "docs", "scripts"]
REF_FILES = [ROOT / "README.md", ROOT / "DESIGN.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_REF_RE = re.compile(r"DESIGN\.md\s+§([A-Za-z0-9][A-Za-z0-9-]*)")
HEADING_RE = re.compile(r"^##\s+§([A-Za-z0-9][A-Za-z0-9-]*)", re.M)

# repo-relative path citations: a known top-level tree + extension, or an
# ALL-CAPS top-level markdown name (README.md, DESIGN.md, ROADMAP.md...)
PATH_REF_RE = re.compile(
    r"(?<![\w/.-])"
    r"((?:docs|scripts|examples|python|rust)/[A-Za-z0-9_./-]+"
    r"\.(?:md|py|rs|sh|yml|toml)"
    r"|[A-Z][A-Z0-9_]+\.md)"
    r"(?![\w/-])")

# generic placeholders used when *describing* the convention itself
# (e.g. DESIGN.md's "cite them as `DESIGN.md §N`"), not real references
PLACEHOLDER_SECTIONS = {"N", "Name"}


def check_links(errors: list) -> None:
    for doc in DOC_FILES:
        text = doc.read_text(encoding="utf-8")
        for match in LINK_RE.finditer(text):
            target = match.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{doc.relative_to(ROOT)}: broken link -> {target}")


def design_sections() -> set:
    design = (ROOT / "DESIGN.md").read_text(encoding="utf-8")
    return set(HEADING_RE.findall(design))


def ref_scanned_files() -> list:
    files = list(REF_FILES)
    for tree in REF_TREES:
        base = ROOT / tree
        if base.exists():
            for p in sorted(base.rglob("*")):
                if p.is_file() and p.suffix in {".rs", ".py", ".md", ".sh"}:
                    files.append(p)
    return files


def check_section_refs(errors: list) -> None:
    sections = design_sections()
    for f in ref_scanned_files():
        try:
            text = f.read_text(encoding="utf-8")
        except UnicodeDecodeError:
            continue
        for match in SECTION_REF_RE.finditer(text):
            # references are written `DESIGN.md §5` or `DESIGN.md §Name`;
            # a trailing sentence word boundary is handled by the charset
            section = match.group(1)
            if section in PLACEHOLDER_SECTIONS:
                continue
            if section not in sections:
                errors.append(
                    f"{f.relative_to(ROOT)}: unresolved reference DESIGN.md §{section} "
                    f"(known: {', '.join(sorted(sections))})")


def check_path_refs(errors: list) -> None:
    for f in ref_scanned_files():
        try:
            text = f.read_text(encoding="utf-8")
        except UnicodeDecodeError:
            continue
        for match in PATH_REF_RE.finditer(text):
            path = match.group(1)
            if not (ROOT / path).exists():
                errors.append(
                    f"{f.relative_to(ROOT)}: cited path does not exist -> {path}")


def main() -> int:
    errors: list = []
    check_links(errors)
    check_section_refs(errors)
    check_path_refs(errors)
    if errors:
        print(f"doc cross-reference check FAILED ({len(errors)} problem(s)):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"doc cross-reference check OK "
          f"({len(DOC_FILES)} markdown files, sections: "
          f"{', '.join(sorted(design_sections()))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
