#!/usr/bin/env python3
"""Merge per-bench JSON emissions into the tracked BENCH_kernels.json
baseline, and validate/gate that baseline.

The Rust bench binaries (rust/benches/*.rs) write one JSON file each when
`KVMIX_BENCH_JSON=<dir>` is set (see rust/src/util/bench.rs `JsonSink`).
This script folds those files into the committed baseline and checks it:

    # regenerate the baseline after a bench run
    KVMIX_BENCH_JSON=/tmp/bench-json cargo bench
    python3 scripts/bench_to_json.py merge --json-dir /tmp/bench-json \
        --out BENCH_kernels.json

    # structural validation (parse + schema + canonical formatting)
    python3 scripts/bench_to_json.py --check BENCH_kernels.json

    # additionally gate the packed-vs-fused speedup (CI bench-smoke)
    python3 scripts/bench_to_json.py --check BENCH_kernels.json \
        --require-speedup 2.0

    # fail if rows measured in both files regressed past a tolerance
    python3 scripts/bench_to_json.py --compare OLD.json NEW.json --tolerance 25

The speedup gate compares, inside the `quant_kernels` bench, the
cold-cache fused reference against the integer-domain packed kernel:
`mean_ns(key_scores_fused/{w}bit) / mean_ns(key_scores_packed/{w}bit)`
and the same for `value_accum_*`, at w in {2, 4} (the pressure ladder's
sub-byte widths; 3-bit also dispatches packed via the Eq. 12 cursor rows
but is ungated — its 11-field words leave less SWAR headroom, see
DESIGN.md §Quantized-Kernels).  Plain `--check` reports the ratios when
both sides are measured but only fails on structural problems;
`--require-speedup` turns unmeasured or missing pairs, and ratios below
the threshold, into failures.  `--require-measured SECTION:SUBSTR`
(repeatable, with --check) fails when no row of SECTION whose name
contains SUBSTR carries a measured mean — the guard CI uses to insist
the merged bench output actually measured the packed rows.

`--compare` is the regression mode: every row measured in BOTH files is
compared by mean_ns, and any row slower in NEW by more than
`--tolerance` percent (default 10) fails the run.  Rows missing or
unmeasured on either side are skipped (the committed baseline may be
all-null placeholders; comparing against it passes with a notice
rather than inventing a gate).

The committed baseline may carry `null` means (placeholder rows written
in an environment without a Rust toolchain); CI's bench-smoke step
regenerates a measured file and gates on that, so the tracked schema and
row names stay authoritative even when the numbers do not.

Exit code 0 = ok, 1 = check failure / bad input.
"""

import argparse
import json
import sys
from pathlib import Path

SCHEMA = 1

# (family, width) pairs the --require-speedup gate must find measured
GATED_PAIRS = [(family, w) for family in ("key_scores", "value_accum")
               for w in (2, 4)]

ENTRY_KEYS = {"name", "mean_ns", "p50_ns", "p95_ns", "min_ns", "iters", "per_s"}


def fail(msg):
    print(f"bench_to_json: {msg}", file=sys.stderr)
    sys.exit(1)


def canonical(doc):
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"


def load_baseline(path):
    try:
        doc = json.loads(path.read_text())
    except FileNotFoundError:
        fail(f"{path}: not found")
    except json.JSONDecodeError as e:
        fail(f"{path}: invalid JSON: {e}")
    return doc


def validate(doc, path):
    errors = []
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA}, got {doc.get('schema')!r}")
    benches = doc.get("benches")
    if not isinstance(benches, dict) or not benches:
        errors.append("missing or empty 'benches' object")
        return errors
    for bench, section in sorted(benches.items()):
        entries = section.get("entries")
        if not isinstance(entries, list):
            errors.append(f"benches.{bench}: 'entries' must be a list")
            continue
        seen = set()
        for i, e in enumerate(entries):
            where = f"benches.{bench}.entries[{i}]"
            if not isinstance(e, dict):
                errors.append(f"{where}: not an object")
                continue
            if set(e) != ENTRY_KEYS:
                errors.append(f"{where}: keys {sorted(e)} != {sorted(ENTRY_KEYS)}")
                continue
            if not isinstance(e["name"], str) or not e["name"]:
                errors.append(f"{where}: bad name {e['name']!r}")
                continue
            if e["name"] in seen:
                errors.append(f"{where}: duplicate name {e['name']!r}")
            seen.add(e["name"])
            for k in ("mean_ns", "p50_ns", "p95_ns", "min_ns", "per_s"):
                v = e[k]
                if v is not None and not isinstance(v, (int, float)):
                    errors.append(f"{where}.{k}: {v!r} is not a number or null")
            if e["iters"] is not None and not isinstance(e["iters"], int):
                errors.append(f"{where}.iters: {e['iters']!r} is not an int or null")
    return errors


def mean_ns(doc, bench, name):
    section = doc.get("benches", {}).get(bench)
    if section is None:
        return None, f"bench section {bench!r} missing"
    for e in section.get("entries", []):
        if isinstance(e, dict) and e.get("name") == name:
            v = e.get("mean_ns")
            if isinstance(v, (int, float)) and v > 0:
                return float(v), None
            return None, f"{bench}:{name} is unmeasured (mean_ns={v!r})"
    return None, f"{bench}:{name} row missing"


def check_speedups(doc, threshold, required):
    """Report fused-vs-packed ratios; return error strings."""
    errors = []
    for family, w in GATED_PAIRS:
        fused_name = f"{family}_fused/{w}bit"
        packed_name = f"{family}_packed/{w}bit"
        fused, ferr = mean_ns(doc, "quant_kernels", fused_name)
        packed, perr = mean_ns(doc, "quant_kernels", packed_name)
        problem = ferr or perr
        if problem:
            if required:
                errors.append(f"speedup gate: {problem}")
            else:
                print(f"  {packed_name}: {problem} (not gated)")
            continue
        ratio = fused / packed
        verdict = "ok" if ratio >= threshold else "BELOW THRESHOLD"
        print(f"  {packed_name}: {ratio:.2f}x vs cold fused "
              f"(>= {threshold:.2f}x required: {verdict})")
        if required and ratio < threshold:
            errors.append(
                f"speedup gate: {packed_name} only {ratio:.2f}x vs "
                f"{fused_name} (need >= {threshold:.2f}x)")
    return errors


def check_measured(doc, specs):
    """Each spec is SECTION:SUBSTR; every matching row must be measured."""
    errors = []
    for spec in specs:
        section_name, _, substr = spec.partition(":")
        if not substr:
            errors.append(f"--require-measured {spec!r}: want SECTION:SUBSTR")
            continue
        section = doc.get("benches", {}).get(section_name)
        if section is None:
            errors.append(f"require-measured: bench section {section_name!r} missing")
            continue
        rows = [e for e in section.get("entries", [])
                if isinstance(e, dict) and substr in str(e.get("name"))]
        if not rows:
            errors.append(f"require-measured: no {section_name} row matches {substr!r}")
            continue
        for e in rows:
            v = e.get("mean_ns")
            if not isinstance(v, (int, float)) or v <= 0:
                errors.append(f"require-measured: {section_name}:{e.get('name')} "
                              f"is unmeasured (mean_ns={v!r})")
    return errors


def cmd_compare(old_path, new_path, tolerance):
    old = load_baseline(old_path)
    new = load_baseline(new_path)
    for doc, path in ((old, old_path), (new, new_path)):
        errors = validate(doc, path)
        if errors:
            for e in errors:
                print(f"bench_to_json: {path}: {e}", file=sys.stderr)
            fail("compare inputs must be structurally valid")
    compared = 0
    skipped = 0
    regressions = []
    for bench, section in sorted(new.get("benches", {}).items()):
        for e in section.get("entries", []):
            name = e.get("name")
            nv = e.get("mean_ns")
            ov, _ = mean_ns(old, bench, name)
            if ov is None or not isinstance(nv, (int, float)) or nv <= 0:
                skipped += 1
                continue
            compared += 1
            delta = (nv - ov) / ov * 100.0
            marker = " REGRESSED" if delta > tolerance else ""
            print(f"  {bench}:{name}: {ov:.0f} -> {nv:.0f} ns "
                  f"({delta:+.1f}%){marker}")
            if delta > tolerance:
                regressions.append(
                    f"regression: {bench}:{name} {delta:+.1f}% "
                    f"(tolerance {tolerance:.0f}%)")
    print(f"compare: {compared} row(s) compared, {skipped} skipped "
          f"(missing/unmeasured on one side)")
    if compared == 0:
        print("compare: nothing comparable — passing with notice "
              "(baseline likely carries placeholder nulls)")
    if regressions:
        for r in regressions:
            print(f"bench_to_json: {r}", file=sys.stderr)
        sys.exit(1)
    print("compare: ok")


def cmd_check(path, threshold, required, require_measured):
    doc = load_baseline(path)
    errors = validate(doc, path)
    text = path.read_text()
    if not errors and text != canonical(doc):
        errors.append(
            "not in canonical format; rewrite with "
            f"`python3 scripts/bench_to_json.py merge --out {path.name}`")
    print(f"{path}: {sum(len(s.get('entries', [])) for s in doc.get('benches', {}).values() if isinstance(s, dict))} entries")
    errors += check_speedups(doc, threshold, required)
    errors += check_measured(doc, require_measured)
    if errors:
        for e in errors:
            print(f"bench_to_json: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"{path}: ok")


def cmd_merge(json_dir, out, note):
    if out.exists():
        doc = load_baseline(out)
        if validate(doc, out):
            fail(f"{out}: existing baseline is invalid; fix or delete it first")
    else:
        doc = {"schema": SCHEMA, "benches": {}}
    if note is not None:
        doc["note"] = note
    merged = 0
    for f in sorted(json_dir.glob("*.json")):
        try:
            emitted = json.loads(f.read_text())
        except json.JSONDecodeError as e:
            fail(f"{f}: invalid JSON from bench run: {e}")
        if emitted.get("schema") != SCHEMA or "bench" not in emitted:
            fail(f"{f}: not a JsonSink emission (schema/bench missing)")
        bench = emitted["bench"]
        entries = emitted.get("entries", [])
        if not entries:
            print(f"  {f.name}: empty (bench skipped), keeping prior rows")
            doc["benches"].setdefault(bench, {"entries": []})
            continue
        doc["benches"][bench] = {"entries": entries}
        merged += 1
        print(f"  {f.name}: {len(entries)} entries -> benches.{bench}")
    if merged == 0 and not doc["benches"]:
        fail(f"{json_dir}: no bench emissions found")
    errors = validate(doc, out)
    if errors:
        for e in errors:
            print(f"bench_to_json: {e}", file=sys.stderr)
        fail("merged document failed validation; not writing")
    out.write_text(canonical(doc))
    print(f"{out}: wrote {merged} merged bench section(s)")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("command", nargs="?", choices=["merge"],
                    help="merge per-bench JSON files into the baseline")
    ap.add_argument("--check", metavar="BASELINE", type=Path,
                    help="validate a baseline file (canonical format, schema)")
    ap.add_argument("--require-speedup", type=float, metavar="X",
                    help="with --check: fail unless packed kernels beat the "
                         "cold fused reference by Xx at 2/4-bit (missing or "
                         "unmeasured rows also fail)")
    ap.add_argument("--require-measured", action="append", default=[],
                    metavar="SECTION:SUBSTR",
                    help="with --check: fail unless every SECTION row whose "
                         "name contains SUBSTR has a measured mean (repeatable)")
    ap.add_argument("--compare", nargs=2, type=Path, metavar=("OLD", "NEW"),
                    help="regression mode: fail if a row measured in both "
                         "files is slower in NEW by more than --tolerance %%")
    ap.add_argument("--tolerance", type=float, default=10.0, metavar="PCT",
                    help="--compare: allowed mean_ns growth in percent "
                         "(default 10)")
    ap.add_argument("--json-dir", type=Path,
                    help="merge: directory of JsonSink emissions "
                         "(the KVMIX_BENCH_JSON dir)")
    ap.add_argument("--out", type=Path, default=Path("BENCH_kernels.json"),
                    help="merge: baseline file to update (default "
                         "BENCH_kernels.json)")
    ap.add_argument("--note", help="merge: replace the baseline's note field")
    args = ap.parse_args()

    if args.command == "merge":
        if args.json_dir is None:
            ap.error("merge requires --json-dir")
        if not args.json_dir.is_dir():
            fail(f"{args.json_dir}: not a directory")
        cmd_merge(args.json_dir, args.out, args.note)
    elif args.compare is not None:
        cmd_compare(args.compare[0], args.compare[1], args.tolerance)
    elif args.check is not None:
        threshold = args.require_speedup if args.require_speedup is not None else 2.0
        cmd_check(args.check, threshold, args.require_speedup is not None,
                  args.require_measured)
    else:
        ap.error("nothing to do: pass `merge`, --check or --compare")


if __name__ == "__main__":
    main()
