#!/usr/bin/env python3
"""Merge per-bench JSON emissions into the tracked BENCH_kernels.json
baseline, and validate/gate that baseline.

The Rust bench binaries (rust/benches/*.rs) write one JSON file each when
`KVMIX_BENCH_JSON=<dir>` is set (see rust/src/util/bench.rs `JsonSink`).
This script folds those files into the committed baseline and checks it:

    # regenerate the baseline after a bench run
    KVMIX_BENCH_JSON=/tmp/bench-json cargo bench
    python3 scripts/bench_to_json.py merge --json-dir /tmp/bench-json \
        --out BENCH_kernels.json

    # structural validation (parse + schema + canonical formatting)
    python3 scripts/bench_to_json.py --check BENCH_kernels.json

    # additionally gate the packed-vs-fused speedup (CI bench-smoke)
    python3 scripts/bench_to_json.py --check BENCH_kernels.json \
        --require-speedup 1.5

The speedup gate compares, inside the `quant_kernels` bench, the
cold-cache fused reference against the integer-domain packed kernel:
`mean_ns(key_scores_fused/{w}bit) / mean_ns(key_scores_packed/{w}bit)`
and the same for `value_accum_*`, at w in {2, 4} (the pressure ladder's
sub-byte widths with word-aligned layouts; 3-bit dispatches to the fused
fallback by design — DESIGN.md §Quantized-Kernels).  Plain `--check`
reports the ratios when both sides are measured but only fails on
structural problems; `--require-speedup` turns unmeasured or missing
pairs, and ratios below the threshold, into failures.

The committed baseline may carry `null` means (placeholder rows written
in an environment without a Rust toolchain); CI's bench-smoke step
regenerates a measured file and gates on that, so the tracked schema and
row names stay authoritative even when the numbers do not.

Exit code 0 = ok, 1 = check failure / bad input.
"""

import argparse
import json
import sys
from pathlib import Path

SCHEMA = 1

# (family, width) pairs the --require-speedup gate must find measured
GATED_PAIRS = [(family, w) for family in ("key_scores", "value_accum")
               for w in (2, 4)]

ENTRY_KEYS = {"name", "mean_ns", "p50_ns", "p95_ns", "min_ns", "iters", "per_s"}


def fail(msg):
    print(f"bench_to_json: {msg}", file=sys.stderr)
    sys.exit(1)


def canonical(doc):
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"


def load_baseline(path):
    try:
        doc = json.loads(path.read_text())
    except FileNotFoundError:
        fail(f"{path}: not found")
    except json.JSONDecodeError as e:
        fail(f"{path}: invalid JSON: {e}")
    return doc


def validate(doc, path):
    errors = []
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA}, got {doc.get('schema')!r}")
    benches = doc.get("benches")
    if not isinstance(benches, dict) or not benches:
        errors.append("missing or empty 'benches' object")
        return errors
    for bench, section in sorted(benches.items()):
        entries = section.get("entries")
        if not isinstance(entries, list):
            errors.append(f"benches.{bench}: 'entries' must be a list")
            continue
        seen = set()
        for i, e in enumerate(entries):
            where = f"benches.{bench}.entries[{i}]"
            if not isinstance(e, dict):
                errors.append(f"{where}: not an object")
                continue
            if set(e) != ENTRY_KEYS:
                errors.append(f"{where}: keys {sorted(e)} != {sorted(ENTRY_KEYS)}")
                continue
            if not isinstance(e["name"], str) or not e["name"]:
                errors.append(f"{where}: bad name {e['name']!r}")
                continue
            if e["name"] in seen:
                errors.append(f"{where}: duplicate name {e['name']!r}")
            seen.add(e["name"])
            for k in ("mean_ns", "p50_ns", "p95_ns", "min_ns", "per_s"):
                v = e[k]
                if v is not None and not isinstance(v, (int, float)):
                    errors.append(f"{where}.{k}: {v!r} is not a number or null")
            if e["iters"] is not None and not isinstance(e["iters"], int):
                errors.append(f"{where}.iters: {e['iters']!r} is not an int or null")
    return errors


def mean_ns(doc, bench, name):
    section = doc.get("benches", {}).get(bench)
    if section is None:
        return None, f"bench section {bench!r} missing"
    for e in section.get("entries", []):
        if isinstance(e, dict) and e.get("name") == name:
            v = e.get("mean_ns")
            if isinstance(v, (int, float)) and v > 0:
                return float(v), None
            return None, f"{bench}:{name} is unmeasured (mean_ns={v!r})"
    return None, f"{bench}:{name} row missing"


def check_speedups(doc, threshold, required):
    """Report fused-vs-packed ratios; return error strings."""
    errors = []
    for family, w in GATED_PAIRS:
        fused_name = f"{family}_fused/{w}bit"
        packed_name = f"{family}_packed/{w}bit"
        fused, ferr = mean_ns(doc, "quant_kernels", fused_name)
        packed, perr = mean_ns(doc, "quant_kernels", packed_name)
        problem = ferr or perr
        if problem:
            if required:
                errors.append(f"speedup gate: {problem}")
            else:
                print(f"  {packed_name}: {problem} (not gated)")
            continue
        ratio = fused / packed
        verdict = "ok" if ratio >= threshold else "BELOW THRESHOLD"
        print(f"  {packed_name}: {ratio:.2f}x vs cold fused "
              f"(>= {threshold:.2f}x required: {verdict})")
        if required and ratio < threshold:
            errors.append(
                f"speedup gate: {packed_name} only {ratio:.2f}x vs "
                f"{fused_name} (need >= {threshold:.2f}x)")
    return errors


def cmd_check(path, threshold, required):
    doc = load_baseline(path)
    errors = validate(doc, path)
    text = path.read_text()
    if not errors and text != canonical(doc):
        errors.append(
            "not in canonical format; rewrite with "
            f"`python3 scripts/bench_to_json.py merge --out {path.name}`")
    print(f"{path}: {sum(len(s.get('entries', [])) for s in doc.get('benches', {}).values() if isinstance(s, dict))} entries")
    errors += check_speedups(doc, threshold, required)
    if errors:
        for e in errors:
            print(f"bench_to_json: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"{path}: ok")


def cmd_merge(json_dir, out, note):
    if out.exists():
        doc = load_baseline(out)
        if validate(doc, out):
            fail(f"{out}: existing baseline is invalid; fix or delete it first")
    else:
        doc = {"schema": SCHEMA, "benches": {}}
    if note is not None:
        doc["note"] = note
    merged = 0
    for f in sorted(json_dir.glob("*.json")):
        try:
            emitted = json.loads(f.read_text())
        except json.JSONDecodeError as e:
            fail(f"{f}: invalid JSON from bench run: {e}")
        if emitted.get("schema") != SCHEMA or "bench" not in emitted:
            fail(f"{f}: not a JsonSink emission (schema/bench missing)")
        bench = emitted["bench"]
        entries = emitted.get("entries", [])
        if not entries:
            print(f"  {f.name}: empty (bench skipped), keeping prior rows")
            doc["benches"].setdefault(bench, {"entries": []})
            continue
        doc["benches"][bench] = {"entries": entries}
        merged += 1
        print(f"  {f.name}: {len(entries)} entries -> benches.{bench}")
    if merged == 0 and not doc["benches"]:
        fail(f"{json_dir}: no bench emissions found")
    errors = validate(doc, out)
    if errors:
        for e in errors:
            print(f"bench_to_json: {e}", file=sys.stderr)
        fail("merged document failed validation; not writing")
    out.write_text(canonical(doc))
    print(f"{out}: wrote {merged} merged bench section(s)")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("command", nargs="?", choices=["merge"],
                    help="merge per-bench JSON files into the baseline")
    ap.add_argument("--check", metavar="BASELINE", type=Path,
                    help="validate a baseline file (canonical format, schema)")
    ap.add_argument("--require-speedup", type=float, metavar="X",
                    help="with --check: fail unless packed kernels beat the "
                         "cold fused reference by Xx at 2/4-bit (missing or "
                         "unmeasured rows also fail)")
    ap.add_argument("--json-dir", type=Path,
                    help="merge: directory of JsonSink emissions "
                         "(the KVMIX_BENCH_JSON dir)")
    ap.add_argument("--out", type=Path, default=Path("BENCH_kernels.json"),
                    help="merge: baseline file to update (default "
                         "BENCH_kernels.json)")
    ap.add_argument("--note", help="merge: replace the baseline's note field")
    args = ap.parse_args()

    if args.command == "merge":
        if args.json_dir is None:
            ap.error("merge requires --json-dir")
        if not args.json_dir.is_dir():
            fail(f"{args.json_dir}: not a directory")
        cmd_merge(args.json_dir, args.out, args.note)
    elif args.check is not None:
        threshold = args.require_speedup if args.require_speedup is not None else 1.5
        cmd_check(args.check, threshold, args.require_speedup is not None)
    else:
        ap.error("nothing to do: pass `merge` or --check")


if __name__ == "__main__":
    main()
