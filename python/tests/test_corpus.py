"""Synthetic corpus: determinism, mask semantics, task structure."""

import numpy as np

from compile import corpus


def test_deterministic():
    a = corpus.batch(np.random.RandomState(1), 4, 64)
    b = corpus.batch(np.random.RandomState(1), 4, 64)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_token_ranges():
    toks, _ = corpus.batch(np.random.RandomState(2), 16, 96)
    assert toks.min() >= 0 and toks.max() < corpus.VOCAB


def test_recall_mask_points_at_value():
    """mask>0 at queried-key positions; tokens[t+1] is the bound value."""
    for seed in range(5):
        s = corpus.gen_recall(np.random.RandomState(seed), 96)
        pos = np.nonzero(s.loss_mask)[0]
        assert len(pos) >= 4
        sep = int(np.nonzero(s.tokens == corpus.SEP)[0][0])
        for t in pos:
            assert s.loss_mask[t] == corpus.ANSWER_WEIGHT
            assert s.tokens[t - 1] == corpus.QRY
            qkey = s.tokens[t]
            assert corpus.KEY_BASE <= qkey < corpus.KEY_BASE + corpus.KEY_COUNT
            v = s.tokens[t + 1]
            assert corpus.VAL_BASE <= v < corpus.VAL_BASE + corpus.VAL_COUNT
            # every binding of this key in the context carries value v
            ks = np.nonzero(s.tokens[:sep] == qkey)[0]
            assert len(ks) >= 1
            for kpos in ks:
                assert s.tokens[kpos + 1] == v


def test_recall_query_offset_controls_distance():
    recent = corpus.gen_recall(np.random.RandomState(0), 96, query_offset=0)
    old = corpus.gen_recall(np.random.RandomState(0), 96, query_offset=10)

    def last_binding(s):
        t = int(np.nonzero(s.loss_mask)[0][0])
        key = s.tokens[t]
        sep = int(np.nonzero(s.tokens == corpus.SEP)[0][0])
        return int(np.nonzero(s.tokens[:sep] == key)[0][-1])

    # larger offset -> the queried key's last binding sits earlier
    assert last_binding(old) < last_binding(recent)


def test_chain_sums_correct():
    for seed in range(5):
        s = corpus.gen_chain(np.random.RandomState(seed), 80)
        pos = np.nonzero(s.loss_mask)[0]
        assert len(pos) > 3
        for t in pos:
            assert s.tokens[t] == corpus.EQL
            ns = [int(s.tokens[t - 3]), int(s.tokens[t - 2]), int(s.tokens[t - 1])]
            assert s.tokens[t + 1] == max(ns)


def test_lm_dynamics_learnable():
    s = corpus.gen_lm(np.random.RandomState(4), 64)
    toks = s.tokens
    # recover offset from first transition and check most steps follow it
    xs = [t - corpus.LM_BASE for t in toks[1:] if t >= corpus.LM_BASE]
    o = (xs[1] - corpus.LM_MULT * xs[0]) % corpus.LM_COUNT
    follows = sum(1 for a, b in zip(xs, xs[1:])
                  if b == (corpus.LM_MULT * a + o) % corpus.LM_COUNT)
    assert follows / (len(xs) - 1) > 0.75


def test_eval_set_fixed():
    a = corpus.eval_set("recall", 4, 64)
    b = corpus.eval_set("recall", 4, 64)
    np.testing.assert_array_equal(a[0], b[0])
