"""Pallas qkv_proj and fused mixed-precision attention vs jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_attn import attn_mixed
from compile.kernels.qkv_proj import qkv_proj


def _qkv_ref(x, pos, lnw, wq, wk, wv, h, hkv, hd):
    xn = ref.rmsnorm(x, lnw)
    t = x.shape[0]
    q = (xn @ wq).reshape(t, h, hd)
    k = (xn @ wk).reshape(t, hkv, hd)
    v = (xn @ wv).reshape(t, hkv, hd)
    return ref.rope(q, pos), ref.rope(k, pos), v


@pytest.mark.parametrize("t", [1, 2, 8, 32, 64])
def test_qkv_proj_matches_ref(t):
    h, hkv, hd, d = 4, 2, 32, 64
    rng = np.random.RandomState(t)
    x = jnp.asarray(rng.randn(t, d).astype(np.float32))
    pos = jnp.asarray(rng.randint(0, 500, size=t).astype(np.int32))
    lnw = jnp.asarray(rng.randn(d).astype(np.float32))
    wq = jnp.asarray((rng.randn(d, h * hd) / 8).astype(np.float32))
    wk = jnp.asarray((rng.randn(d, hkv * hd) / 8).astype(np.float32))
    wv = jnp.asarray((rng.randn(d, hkv * hd) / 8).astype(np.float32))
    q, k, v = qkv_proj(x, pos, lnw, wq, wk, wv, n_heads=h, n_kv_heads=hkv,
                       head_dim=hd, block_t=min(32, t))
    qr, kr, vr = _qkv_ref(x, pos, lnw, wq, wk, wv, h, hkv, hd)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(k), np.asarray(kr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=2e-5)


@pytest.mark.parametrize("boundary", [0, 32, 64, 96])
@pytest.mark.parametrize("k_bits,v_bits", [(2, 2), (3, 4), (2, 4)])
def test_attn_mixed_matches_ref(boundary, k_bits, v_bits):
    h, hkv, hd, t = 4, 2, 32, 96
    rng = np.random.RandomState(boundary + k_bits)
    q = jnp.asarray(rng.randn(h, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    out = attn_mixed(q, k, v, boundary, k_bits=k_bits, v_bits=v_bits, group=32)
    want = ref.attn_mixed_ref(q, k, v, boundary, k_bits, v_bits, group=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_attn_boundary_zero_is_full_precision():
    """boundary=0 must equal plain softmax attention."""
    h, hkv, hd, t = 4, 2, 32, 64
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(h, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    out2 = attn_mixed(q, k, v, 0, k_bits=1, v_bits=1, group=32)
    want = ref.attn_mixed_ref(q, k, v, 0, 4, 4, group=32)  # bits irrelevant
    np.testing.assert_allclose(np.asarray(out2), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_attn_quant_error_shrinks_with_bits():
    """More bits on the history -> closer to full-precision output."""
    h, hkv, hd, t = 4, 2, 32, 128
    rng = np.random.RandomState(5)
    q = jnp.asarray(rng.randn(h, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    full = np.asarray(ref.attn_mixed_ref(q, k, v, 0, 4, 4))
    errs = []
    for bits in (1, 2, 3, 4):
        out = np.asarray(attn_mixed(q, k, v, 128, k_bits=bits, v_bits=bits))
        errs.append(np.abs(out - full).mean())
    assert errs[0] > errs[1] > errs[2] > errs[3]


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([32, 64]),
       st.sampled_from([0, 32]))
@settings(max_examples=10, deadline=None)
def test_attn_mixed_hypothesis(seed, t, boundary):
    h, hkv, hd = 4, 2, 32
    rng = np.random.RandomState(seed % 10_000)
    scale = rng.uniform(0.1, 3.0)
    q = jnp.asarray((rng.randn(h, hd) * scale).astype(np.float32))
    k = jnp.asarray((rng.randn(t, hkv, hd) * scale).astype(np.float32))
    v = jnp.asarray((rng.randn(t, hkv, hd) * scale).astype(np.float32))
    out = attn_mixed(q, k, v, boundary, k_bits=2, v_bits=2, group=32)
    want = ref.attn_mixed_ref(q, k, v, boundary, 2, 2, group=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-4)
