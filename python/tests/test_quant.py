"""Quantization kernels vs ref oracles — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pack3 import pack3 as pl_pack3, unpack3 as pl_unpack3
from compile.kernels.quant_kv import fq_key_per_channel, fq_value_per_token


# ---------------------------------------------------------------------------
# Reference-level invariants
# ---------------------------------------------------------------------------
@given(st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_fake_quant_error_bound(bits, seed):
    """|x - fq(x)| <= s/2 + eps per element (round-to-nearest within range)."""
    rng = np.random.RandomState(seed % 10_000)
    x = rng.randn(4, 32).astype(np.float32) * rng.uniform(0.01, 10)
    qmax = (1 << bits) - 1
    s, mn = ref.quant_params(jnp.asarray(x), qmax, axis=1)
    fq = ref.dequantize(ref.quantize(jnp.asarray(x), s, mn, qmax), s, mn)
    err = np.abs(np.asarray(fq) - x)
    bound = np.asarray(s) / 2 + 1e-5
    assert (err <= bound + 1e-6 * np.abs(x)).all()


def test_fake_quant_constant_group():
    """A constant group must quantize losslessly (s==0 guard)."""
    x = jnp.full((1, 32), 3.25, dtype=jnp.float32)
    out = ref.fake_quant(x, 2, axis=1)
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=0, atol=1e-7)


@given(st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_fake_quant_endpoints_exact(bits, seed):
    """Group min and max are representable exactly (asymmetric quant)."""
    rng = np.random.RandomState(seed % 10_000)
    x = rng.randn(32).astype(np.float32)
    out = np.asarray(ref.fake_quant(jnp.asarray(x), bits, axis=0))
    i_mn, i_mx = int(np.argmin(x)), int(np.argmax(x))
    assert abs(out[i_mn] - x[i_mn]) < 1e-5
    assert abs(out[i_mx] - x[i_mx]) < 1e-4 * max(1.0, abs(x[i_mx]))


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_pack3_roundtrip_numpy(seed, nblocks):
    rng = np.random.RandomState(seed % 10_000)
    q = rng.randint(0, 8, size=11 * nblocks)
    q[10::11] &= 0x3
    words = ref.pack3(q)
    assert words.dtype == np.uint32 and words.shape == (nblocks,)
    np.testing.assert_array_equal(ref.unpack3(words), q)


@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_uniform_roundtrip(bits, seed):
    rng = np.random.RandomState(seed % 10_000)
    per = 32 // bits
    q = rng.randint(0, 1 << bits, size=per * 7)
    np.testing.assert_array_equal(ref.unpack_uniform(ref.pack_uniform(q, bits), bits), q)


def test_pack3_density():
    """Eq.12 claim: 11 elements per word vs 10 for naive 3-bit packing."""
    assert ref.PACK3_BLOCK == 11


def test_pack3_pallas_matches_ref():
    rng = np.random.RandomState(0)
    q = rng.randint(0, 8, size=11 * 300)
    q[10::11] &= 0x3
    words_ref = ref.pack3(q)
    words_pl = np.asarray(pl_pack3(jnp.asarray(q, dtype=jnp.int32)))
    np.testing.assert_array_equal(words_pl.astype(np.uint32), words_ref)
    unpacked = np.asarray(pl_unpack3(jnp.asarray(words_ref)))
    np.testing.assert_array_equal(unpacked, q)


def test_fq3_blockwise_lower_precision_last_element():
    """Element 10 of each 11-block gets 2 bits -> error can exceed the 3-bit
    bound but must stay within the 2-bit bound."""
    rng = np.random.RandomState(3)
    x = rng.randn(8, 33).astype(np.float32)
    out = np.asarray(ref.fake_quant_3bit_blockwise(jnp.asarray(x)))
    s = (x.max(1) - x.min(1)) / 7.0
    err = np.abs(out - x)
    # 2-bit elements are clipped to q<=3 -> worst error <= range - 3*s... the
    # universal bound is |err| <= range (sanity) and 3-bit slots <= s/2.
    idx3 = np.arange(33) % 11 != 10
    assert (err[:, idx3] <= s[:, None] / 2 + 1e-5).all()
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# Pallas fake-quant kernels vs ref
#
# Quantization is discontinuous: when (x - min)/s lands within 1 ulp of a
# rounding boundary, two separately-compiled fp pipelines may legitimately
# pick adjacent buckets.  Either bucket then has error ~ s/2 vs the
# original, so the parity assertion is: exact match for >= 99.5% of
# elements AND every element within one quantization step of the oracle.
# ---------------------------------------------------------------------------
def assert_quant_close(out, want, bits):
    out, want = np.asarray(out), np.asarray(want)
    exact = np.isclose(out, want, atol=1e-6)
    assert exact.mean() >= 0.995, f"only {exact.mean():.4f} exact"
    step = (want.max() - want.min()) / ((1 << bits) - 1)
    assert np.abs(out - want).max() <= step + 1e-5


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
@pytest.mark.parametrize("t,hkv,hd", [(32, 2, 32), (96, 2, 32), (64, 4, 64)])
def test_fq_key_kernel_matches_ref(bits, t, hkv, hd):
    rng = np.random.RandomState(bits * 100 + t)
    k = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    out = fq_key_per_channel(k, bits=bits, group=32)
    want = ref.fake_quant_key_per_channel(k, bits, group=32)
    assert_quant_close(out, want, bits)


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
@pytest.mark.parametrize("t,hkv,hd", [(32, 2, 32), (96, 2, 32), (64, 4, 64)])
def test_fq_value_kernel_matches_ref(bits, t, hkv, hd):
    rng = np.random.RandomState(bits * 100 + t + 1)
    v = jnp.asarray(rng.randn(t, hkv, hd).astype(np.float32))
    out = fq_value_per_token(v, bits=bits, group=32)
    want = ref.fake_quant_value_per_token(v, bits, group=32)
    assert_quant_close(out, want, bits)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 3, 4]),
       st.sampled_from([32, 64, 128]))
@settings(max_examples=12, deadline=None)
def test_fq_key_kernel_hypothesis(seed, bits, t):
    rng = np.random.RandomState(seed % 10_000)
    k = jnp.asarray((rng.randn(t, 2, 32) * rng.uniform(0.1, 5)).astype(np.float32))
    out = fq_key_per_channel(k, bits=bits, group=32)
    want = ref.fake_quant_key_per_channel(k, bits, group=32)
    assert_quant_close(out, want, bits)
