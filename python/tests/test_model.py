"""Model graphs: shapes, artifact-graph == forward_jnp parity, profiler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, profiler
from compile.model import (ModelConfig, forward_jnp, init_params, logits_graph,
                           loss_fn, post_graph, pre_graph, flat_weights,
                           unflatten)

CFG = ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, n_kv_heads=1,
                  head_dim=16, d_ff=64)


@pytest.fixture(scope="module")
def params():
    return jax.tree_util.tree_map(jnp.asarray, init_params(CFG, seed=3))


def test_forward_shapes(params):
    toks = jnp.zeros((2, 10), dtype=jnp.int32)
    logits = forward_jnp(params, toks, CFG)
    assert logits.shape == (2, 10, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_causality(params):
    """Changing a future token must not change past logits."""
    rng = np.random.RandomState(0)
    t1 = rng.randint(0, CFG.vocab, size=(1, 12)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % CFG.vocab
    l1 = forward_jnp(params, jnp.asarray(t1), CFG)
    l2 = forward_jnp(params, jnp.asarray(t2), CFG)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                               atol=1e-5)
    assert np.abs(np.asarray(l1[0, -1]) - np.asarray(l2[0, -1])).max() > 1e-4


def test_artifact_graphs_compose_to_forward(params):
    """pre -> (jnp attention) -> post -> logits must reproduce forward_jnp.

    This is exactly the decomposition the Rust engine performs; if this
    passes and Rust matches the goldens, the whole pipeline is consistent.
    """
    t = 8
    rng = np.random.RandomState(1)
    toks = rng.randint(0, CFG.vocab, size=(1, t)).astype(np.int32)
    want = np.asarray(forward_jnp(params, jnp.asarray(toks), CFG))[0]

    pre, post, logits_g = pre_graph(CFG), post_graph(CFG), logits_graph(CFG)
    h = jnp.take(params["embed"], jnp.asarray(toks[0]), axis=0)
    pos = jnp.arange(t, dtype=jnp.int32)
    rep = CFG.n_heads // CFG.n_kv_heads
    for lyr in params["layers"]:
        q, k, v = pre(h, pos, lyr["ln1"], lyr["wq"], lyr["wk"], lyr["wv"])
        kk, vv = jnp.repeat(k, rep, axis=1), jnp.repeat(v, rep, axis=1)
        s = jnp.einsum("qhd,khd->hqk", q, kk) / np.sqrt(CFG.head_dim)
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", p, vv).reshape(t, CFG.q_dim)
        h = post(attn, h, lyr["wo"], lyr["ln2"], lyr["wg"], lyr["wu"], lyr["wd"])
    got = np.asarray(logits_g(h, params["lnf"], params["lm_head"]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_flatten_unflatten_roundtrip(params):
    flat = [a for _, a in flat_weights(CFG, params)]
    p2 = unflatten(CFG, [jnp.asarray(a) for a in flat])
    np.testing.assert_array_equal(np.asarray(p2["embed"]),
                                  np.asarray(params["embed"]))
    np.testing.assert_array_equal(np.asarray(p2["layers"][1]["wk"]),
                                  np.asarray(params["layers"][1]["wk"]))


def test_loss_masked_only(params):
    """Zero mask -> zero-ish denominator guard; partial mask selects positions."""
    toks = jnp.zeros((1, 8), dtype=jnp.int32)
    zero = loss_fn(params, toks, jnp.zeros((1, 8)), CFG)
    assert bool(jnp.isfinite(zero))


def test_profiler_grad_norms_positive(params):
    rng = np.random.RandomState(0)
    prompts, masks = corpus.batch(rng, 2, 24)
    prompts = prompts % CFG.vocab
    ks, vs = profiler.grad_norms(CFG, params, prompts, masks)
    assert ks.shape == (CFG.n_layers,) and vs.shape == (CFG.n_layers,)
    assert (ks > 0).all() and (vs > 0).all()


def test_allocate_split():
    ks = np.array([5.0, 1.0, 3.0, 2.0, 0.5, 0.1, 4.0, 0.2])
    vs = np.array([0.1, 5.0, 0.2, 4.0, 3.0, 0.3, 0.4, 0.5])
    plan = profiler.allocate(ks, vs, high_frac=0.25)
    assert plan.k_bits.count(3) == 2 and plan.v_bits.count(4) == 2
    assert plan.k_bits[0] == 3 and plan.k_bits[6] == 3      # top-2 K layers
    assert plan.v_bits[1] == 4 and plan.v_bits[3] == 4      # top-2 V layers
    assert plan.k_rpc[0] == 0.2 and plan.k_rpc[1] == 0.1
    # paper's headline arithmetic: 20% of 32 layers at 3/4 bit
    ks32 = np.arange(32, dtype=float)
    plan32 = profiler.allocate(ks32, ks32, high_frac=0.1875)
    assert abs(plan32.avg_k_bits - 2.1875) < 1e-9
    assert abs(plan32.avg_v_bits - 2.375) < 1e-9


def test_allocate_extremes():
    ks = np.arange(8.0)
    p0 = profiler.allocate(ks, ks, high_frac=0.0)
    assert set(p0.k_bits) == {2} and set(p0.v_bits) == {2}
    p1 = profiler.allocate(ks, ks, high_frac=1.0)
    assert set(p1.k_bits) == {3} and set(p1.v_bits) == {4}
