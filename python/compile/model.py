"""L2: the reproduction model — a Llama-style decoder in JAX.

Two forward paths share one parameter set:

  * ``forward_jnp``  — pure-jnp, differentiable; used for training, the
    KVmix profiler (gradient norms of W_k / W_v), and golden logits.
  * artifact graphs — ``decode_pre`` / ``decode_post`` / ``logits_head`` /
    ``profiler_grads``; the *pre* graph calls the L1 Pallas kernel
    (kernels.qkv_proj) so its lowering lands inside the HLO the Rust
    runtime executes.  All weights are runtime *parameters* of the
    executables (never baked constants) so one executable serves every
    layer; Rust feeds per-layer weight buffers (canonical order below).

Canonical weight order (manifest.json / weights.bin / executable params):

    embed,
    [per layer: ln1, wq, wk, wv, wo, ln2, wg, wu, wd]  x n_layers,
    lnf, lm_head
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.qkv_proj import qkv_proj


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 8
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 256
    group: int = 32          # KV quant group size (= paper's 32)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def to_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)


LAYER_KEYS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, Any]:
    rng = np.random.RandomState(seed)

    def mat(n_in, n_out):
        return (rng.randn(n_in, n_out) * (1.0 / np.sqrt(n_in))).astype(np.float32)

    params: dict[str, Any] = {
        "embed": (rng.randn(cfg.vocab, cfg.d_model) * 0.02).astype(np.float32),
        "layers": [],
        "lnf": np.ones(cfg.d_model, dtype=np.float32),
        "lm_head": mat(cfg.d_model, cfg.vocab),
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "ln1": np.ones(cfg.d_model, dtype=np.float32),
            "wq": mat(cfg.d_model, cfg.q_dim),
            "wk": mat(cfg.d_model, cfg.kv_dim),
            "wv": mat(cfg.d_model, cfg.kv_dim),
            "wo": mat(cfg.q_dim, cfg.d_model),
            "ln2": np.ones(cfg.d_model, dtype=np.float32),
            "wg": mat(cfg.d_model, cfg.d_ff),
            "wu": mat(cfg.d_model, cfg.d_ff),
            "wd": mat(cfg.d_ff, cfg.d_model),
        })
    return params


def flat_weights(cfg: ModelConfig, params: dict[str, Any]) -> list[tuple[str, np.ndarray]]:
    """Canonical (name, array) list — the manifest/weights.bin order."""
    out = [("embed", np.asarray(params["embed"]))]
    for i, lyr in enumerate(params["layers"]):
        for k in LAYER_KEYS:
            out.append((f"layers.{i}.{k}", np.asarray(lyr[k])))
    out.append(("lnf", np.asarray(params["lnf"])))
    out.append(("lm_head", np.asarray(params["lm_head"])))
    return out


# ---------------------------------------------------------------------------
# Differentiable full-sequence forward (training / profiler / goldens)
# ---------------------------------------------------------------------------
def _attention(q, k, v, cfg: ModelConfig):
    """q: [B,T,H,hd], k/v: [B,T,Hkv,hd] — causal GQA attention."""
    b, t, h, hd = q.shape
    rep = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    return out.reshape(b, t, h * hd)


def forward_jnp(params: dict[str, Any], tokens: jnp.ndarray,
                cfg: ModelConfig) -> jnp.ndarray:
    """tokens: [B, T] int32 -> logits [B, T, vocab]."""
    b, t = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    pos = jnp.arange(t, dtype=jnp.int32)
    for lyr in params["layers"]:
        hn = ref.rmsnorm(h, lyr["ln1"])
        q = (hn @ lyr["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (hn @ lyr["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        v = (hn @ lyr["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        q = ref.rope(q, pos)
        k = ref.rope(k, pos)
        h = h + _attention(q, k, v, cfg) @ lyr["wo"]
        hn2 = ref.rmsnorm(h, lyr["ln2"])
        h = h + (ref.silu(hn2 @ lyr["wg"]) * (hn2 @ lyr["wu"])) @ lyr["wd"]
    return ref.rmsnorm(h, params["lnf"]) @ params["lm_head"]


def loss_fn(params: dict[str, Any], tokens: jnp.ndarray, mask: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """Masked next-token cross-entropy.  mask[b, t] weights the prediction
    made *at* position t (of tokens[b, t+1])."""
    logits = forward_jnp(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    w = mask[:, :-1]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


# ---------------------------------------------------------------------------
# Artifact graphs (AOT-lowered; weights are runtime parameters)
# ---------------------------------------------------------------------------
def pre_graph(cfg: ModelConfig):
    """(hidden[T,D], pos[T] i32, ln1, wq, wk, wv) -> q[T,H,hd], k[T,Hkv,hd],
    v[T,Hkv,hd] — RMSNorm + QKV proj + RoPE via the Pallas kernel.  Used
    for both decode (T = batch rows, per-row positions) and prefill."""

    def f(hidden, pos, ln1, wq, wk, wv):
        return qkv_proj(hidden, pos, ln1, wq, wk, wv,
                        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.head_dim,
                        block_t=min(32, hidden.shape[0]))

    return f

def post_graph(cfg: ModelConfig):
    """(attn[T,H*hd], resid[T,D], wo, ln2, wg, wu, wd) -> hidden'[T,D]."""

    def f(attn, resid, wo, ln2, wg, wu, wd):
        h = resid + attn @ wo
        hn = ref.rmsnorm(h, ln2)
        return h + (ref.silu(hn @ wg) * (hn @ wu)) @ wd

    return f


def logits_graph(cfg: ModelConfig):
    """(hidden[T,D], lnf, lm_head) -> logits[T, vocab]."""

    def f(hidden, lnf, lm_head):
        return ref.rmsnorm(hidden, lnf) @ lm_head

    return f


def profiler_graph(cfg: ModelConfig):
    """(tokens[B,T], mask[B,T], *flat weights) -> (loss, k_norms[L], v_norms[L]).

    The KVmix profiler's gradient computation (paper Eq. 10) as a single
    lowered graph so the *Rust* profiler can run importance analysis through
    PJRT with no python on the path.
    """

    def f(tokens, mask, *flat):
        params = unflatten(cfg, list(flat))

        def loss_of_kv(kvs):
            p2 = {**params, "layers": [
                {**lyr, "wk": kvs[i][0], "wv": kvs[i][1]}
                for i, lyr in enumerate(params["layers"])]}
            return loss_fn(p2, tokens, mask, cfg)

        kvs = [(l["wk"], l["wv"]) for l in params["layers"]]
        loss, grads = jax.value_and_grad(loss_of_kv)(kvs)
        k_norms = jnp.stack([jnp.linalg.norm(g[0]) for g in grads])
        v_norms = jnp.stack([jnp.linalg.norm(g[1]) for g in grads])
        return loss, k_norms, v_norms

    return f


def unflatten(cfg: ModelConfig, flat: list[jnp.ndarray]) -> dict[str, Any]:
    """Inverse of flat_weights (same canonical order)."""
    it = iter(flat)
    params: dict[str, Any] = {"embed": next(it), "layers": []}
    for _ in range(cfg.n_layers):
        params["layers"].append({k: next(it) for k in LAYER_KEYS})
    params["lnf"] = next(it)
    params["lm_head"] = next(it)
    rest = list(it)
    assert not rest, f"{len(rest)} extra weights"
    return params
