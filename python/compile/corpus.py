"""Synthetic structured corpus for the KVmix reproduction.

The paper evaluates on LongBench (long-context retrieval-ish tasks), GSM8K
(multi-step reasoning) and Wikitext-2 (language modelling).  We cannot ship
those datasets nor a 7B model, so we train a tiny decoder on three synthetic
tasks that stress the same properties of the KV cache (see DESIGN.md §3):

  * ``lm``     — a learnable pseudo-language (per-sequence hidden offset,
                 first-order deterministic dynamics + noise floor).
                 Wikitext-2 analog: held-out perplexity.
  * ``recall`` — key/value pairs scattered in the context, queried at the
                 end.  LongBench analog: accuracy of retrieving *old*
                 (hence quantized) KV entries.
  * ``chain``  — running modular sums emitted at checkpoints; every token
                 contributes to the answer.  GSM8K analog: multi-step exact
                 state tracking.

All generators are deterministic in their seed so the Rust harness can
re-generate identical workloads (mirrored in ``rust/src/harness/workload.rs``;
parity is covered by golden tests).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# Token space (vocab = 512) — keep in sync with rust/src/harness/workload.rs
# ---------------------------------------------------------------------------
VOCAB = 512
PAD, BOS, EOS, SEP, QRY, ANS, EQL = 0, 1, 2, 3, 4, 5, 6

NUM_BASE, NUM_COUNT = 10, 16          # chain-task "numbers"   [10, 26)
KEY_BASE, KEY_COUNT = 100, 48         # recall keys            [100, 148)
VAL_BASE, VAL_COUNT = 200, 48         # recall values          [200, 248)
LM_BASE, LM_COUNT = 300, 212          # lm alphabet            [300, 512)
ANSWER_WEIGHT = 4.0                   # loss upweight on task answers

LM_NOISE = 0.05                       # unpredictable-token floor for lm task
LM_MULT = 3                           # lm dynamics: x' = (3x + o) mod LM_COUNT


@dataclasses.dataclass
class Sample:
    """One training/eval sequence.

    ``tokens``   int32 [T]  (PAD-padded)
    ``loss_mask`` f32  [T]  weight of the *prediction at* position t
                           (i.e. the loss on predicting tokens[t+1..]).
    """

    tokens: np.ndarray
    loss_mask: np.ndarray

    def __post_init__(self) -> None:
        assert self.tokens.shape == self.loss_mask.shape


def _pad(tokens: list[int], mask: list[float], seq_len: int) -> Sample:
    t = np.full(seq_len, PAD, dtype=np.int32)
    m = np.zeros(seq_len, dtype=np.float32)
    n = min(len(tokens), seq_len)
    t[:n] = tokens[:n]
    m[:n] = mask[:n]
    return Sample(t, m)


# ---------------------------------------------------------------------------
# Task generators
# ---------------------------------------------------------------------------
def gen_lm(rng: np.random.RandomState, seq_len: int) -> Sample:
    """Pseudo-language: x_{t+1} = LM_MULT*x_t + o (mod LM_COUNT), rare noise.

    The hidden offset ``o`` is recoverable from the first transition, so a
    trained model reaches low (but, because of the noise floor, not zero)
    perplexity.  Loss applies to every emitted lm token after the second.
    """
    o = int(rng.randint(1, 16))
    x = int(rng.randint(LM_COUNT))
    toks: list[int] = [BOS, LM_BASE + x]
    mask: list[float] = [0.0, 0.0]
    for _ in range(seq_len - 3):
        if rng.rand() < LM_NOISE:
            x = int(rng.randint(LM_COUNT))
        else:
            x = (LM_MULT * x + o) % LM_COUNT
        toks.append(LM_BASE + x)
        # the *previous* position predicts this token
        mask[-1] = 1.0
        mask.append(0.0)
    toks.append(EOS)
    mask[-1] = 1.0
    mask.append(0.0)
    return _pad(toks, mask, seq_len)


N_DISTINCT_PAIRS = 16                 # distinct (key, value) bindings per doc


def gen_recall(rng: np.random.RandomState, seq_len: int,
               query_offset: int | None = None, n_queries: int = 8) -> Sample:
    """In-context associative recall (induction-head format).

    A document binds ``N_DISTINCT_PAIRS`` distinct keys to values and
    repeats the bindings (shuffled) to fill the context; queries at the end
    are ``QRY k`` with the loss at the *key* position predicting the bound
    value — the classic [k][v]…[k][?]→v induction pattern.

    ``query_offset`` (0 = key whose *last* binding is most recent, larger =
    older) lets the eval harness stress retrieval distance — old bindings
    live in the quantized region of the cache.
    """
    n_distinct = min(N_DISTINCT_PAIRS, KEY_COUNT)
    keys = rng.choice(KEY_COUNT, size=n_distinct, replace=False)
    vals = rng.randint(VAL_COUNT, size=n_distinct)
    budget = seq_len - 2 - 3 * n_queries - 1
    toks: list[int] = [BOS]
    mask: list[float] = [0.0]
    order: list[int] = []
    while len(toks) + 2 <= budget:
        if not order:
            order = list(rng.permutation(n_distinct))
        i = order.pop()
        toks += [KEY_BASE + int(keys[i]), VAL_BASE + int(vals[i])]
        mask += [0.0, 0.0]
    toks.append(SEP)
    mask.append(0.0)
    # last-occurrence recency order for query_offset targeting
    last_pos = {}
    for t, tok in enumerate(toks):
        if KEY_BASE <= tok < KEY_BASE + KEY_COUNT:
            last_pos[tok] = t
    by_recency = sorted(last_pos, key=lambda k: -last_pos[k])
    for qn in range(n_queries):
        if len(toks) + 3 > seq_len:
            break
        if qn == 0 and query_offset is not None:
            key_tok = by_recency[query_offset % len(by_recency)]
            qi = int(np.nonzero(keys == key_tok - KEY_BASE)[0][0])
        else:
            qi = int(rng.randint(n_distinct))
        toks += [QRY, KEY_BASE + int(keys[qi]), VAL_BASE + int(vals[qi])]
        # the key position predicts the bound value
        mask += [0.0, ANSWER_WEIGHT, 0.0]
    toks.append(EOS)
    mask.append(0.0)
    return _pad(toks, mask, seq_len)


def gen_chain(rng: np.random.RandomState, seq_len: int) -> Sample:
    """Exact-state selection: `n1 n2 n3 EQL m` groups where
    m = max(n1, n2, n3) — every answer requires the *exact* values of the
    three preceding number tokens (GSM8K analog: step-local computation
    whose answer is corrupted by any KV error on the operands)."""
    toks: list[int] = [BOS]
    mask: list[float] = [0.0]
    while len(toks) + 6 < seq_len:
        ns = [int(rng.randint(NUM_COUNT)) for _ in range(3)]
        for n in ns:
            toks.append(NUM_BASE + n)
            mask.append(0.0)
        toks.append(EQL)
        mask.append(ANSWER_WEIGHT)    # EQL position predicts the max token
        toks.append(NUM_BASE + max(ns))
        mask.append(0.0)
    toks.append(EOS)
    mask.append(0.0)
    return _pad(toks, mask, seq_len)


TASKS = {"lm": gen_lm, "recall": gen_recall, "chain": gen_chain}
TRAIN_MIX = (("lm", 0.2), ("recall", 0.4), ("chain", 0.4))


def batch(rng: np.random.RandomState, batch_size: int, seq_len: int,
          task: str | None = None) -> tuple[np.ndarray, np.ndarray]:
    """A [B, T] token batch and its [B, T] loss-mask, drawn from TRAIN_MIX
    (or a single ``task``)."""
    toks = np.zeros((batch_size, seq_len), dtype=np.int32)
    mask = np.zeros((batch_size, seq_len), dtype=np.float32)
    names = [n for n, _ in TRAIN_MIX]
    probs = np.array([p for _, p in TRAIN_MIX])
    for b in range(batch_size):
        name = task or names[int(rng.choice(len(names), p=probs))]
        s = TASKS[name](rng, seq_len)
        toks[b], mask[b] = s.tokens, s.loss_mask
    return toks, mask


def eval_set(task: str, n: int, seq_len: int, seed: int = 1234) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.RandomState(seed + hash(task) % 1000)
    return batch(rng, n, seq_len, task=task)
