"""Build-time training of the reproduction checkpoint.

Trains the tiny Llama-style decoder (model.py) on the synthetic task
mixture (corpus.py) with hand-rolled Adam, logging the loss curve to
``train_log.json``.  Runs once; ``aot.py``
caches the resulting ``checkpoint.npz``.
"""

from __future__ import annotations

import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, forward_jnp, init_params, loss_fn


def adam_init(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def make_step(cfg: ModelConfig, lr: float = 3e-3, b1=0.9, b2=0.99, eps=1e-8,
              warmup: int = 50):
    @jax.jit
    def step(params, opt, tokens, mask, t):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, mask, cfg)
        lr_t = lr * jnp.minimum(1.0, (t + 1) / warmup)
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
        mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** (t + 1)), m)
        vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** (t + 1)), v)
        params = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr_t * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
        return params, {"m": m, "v": v, "t": t + 1}, loss

    return step


def train(cfg: ModelConfig, steps: int = 600, batch_size: int = 16,
          seq_len: int = 160, seed: int = 0,
          log_path: str | None = None) -> tuple[dict[str, Any], list[float]]:
    rng = np.random.RandomState(seed)
    params = jax.tree_util.tree_map(jnp.asarray, init_params(cfg, seed))
    opt = adam_init(params)
    step = make_step(cfg)
    losses: list[float] = []
    t0 = time.time()
    for i in range(steps):
        toks, mask = corpus.batch(rng, batch_size, seq_len)
        params, opt, loss = step(params, opt, jnp.asarray(toks), jnp.asarray(mask), i)
        losses.append(float(loss))
        if i % 50 == 0 or i == steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
    if log_path:
        with open(log_path, "w") as f:
            json.dump({"steps": steps, "batch_size": batch_size,
                       "seq_len": seq_len, "seconds": time.time() - t0,
                       "loss": losses}, f)
    return jax.tree_util.tree_map(np.asarray, params), losses


def eval_task_metrics(cfg: ModelConfig, params, n: int = 32,
                      seq_len: int = 160) -> dict[str, float]:
    """Held-out metrics: lm perplexity, recall accuracy, chain accuracy."""
    out: dict[str, float] = {}
    fwd = jax.jit(lambda p, t: forward_jnp(p, t, cfg))
    for task in ("lm", "recall", "chain"):
        toks, mask = corpus.eval_set(task, n, seq_len, seed=999)
        logits = fwd(params, jnp.asarray(toks))
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = toks[:, 1:]
        nll = -np.asarray(jnp.take_along_axis(logp, jnp.asarray(tgt)[..., None], axis=-1))[..., 0]
        w = mask[:, :-1]
        denom = max(w.sum(), 1.0)
        out[f"{task}_ppl"] = float(np.exp((nll * w).sum() / denom))
        pred = np.asarray(jnp.argmax(logits[:, :-1], axis=-1))
        out[f"{task}_acc"] = float(((pred == tgt) * w).sum() / denom)
    return out


if __name__ == "__main__":
    cfg = ModelConfig()
    params, losses = train(cfg, steps=200)
    print(eval_task_metrics(cfg, params))
