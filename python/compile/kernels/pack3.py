"""Pallas kernels for the paper's 3-bit packing (Eq. 12).

11 quantized elements per 32-bit word: elements 0..9 use 3 bits
(q_max = 7), element 10 uses the remaining 2 bits (q_max = 3) — a 10%
density win over naive 10-per-word 3-bit packing.

The production pack/unpack lives in Rust (`rust/src/quant/pack.rs`); these
kernels demonstrate the same bit schedule as a vectorized TPU kernel and
pin the layout both implementations are tested against (ref.pack3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 11  # elements per packed word


def _pack_kernel(q_ref, o_ref):
    q = q_ref[...].astype(jnp.uint32)           # [W, 11]
    w = jnp.zeros(q.shape[0], dtype=jnp.uint32)
    for i in range(10):
        w = w | ((q[:, i] & 0x7) << (3 * i))
    w = w | ((q[:, 10] & 0x3) << 30)
    o_ref[...] = w


def _unpack_kernel(w_ref, o_ref):
    w = w_ref[...].astype(jnp.uint32)           # [W]
    cols = [((w >> (3 * i)) & 0x7).astype(jnp.int32) for i in range(10)]
    cols.append(((w >> 30) & 0x3).astype(jnp.int32))
    o_ref[...] = jnp.stack(cols, axis=1)


@functools.partial(jax.jit, static_argnames=("block_w",))
def pack3(q: jnp.ndarray, block_w: int = 128) -> jnp.ndarray:
    """q: int32 [N] with N % 11 == 0, values pre-clipped per Eq. 12.
    Returns uint32 [N / 11]."""
    n = q.shape[0]
    assert n % BLOCK == 0
    words = n // BLOCK
    bw = min(block_w, words)
    # pad word count to a multiple of the tile
    pad = (-words) % bw
    q2 = jnp.pad(q.reshape(words, BLOCK), ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _pack_kernel,
        grid=((words + pad) // bw,),
        in_specs=[pl.BlockSpec((bw, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((words + pad,), jnp.uint32),
        interpret=True,
    )(q2)
    return out[:words]


@functools.partial(jax.jit, static_argnames=("block_w",))
def unpack3(w: jnp.ndarray, block_w: int = 128) -> jnp.ndarray:
    """w: uint32 [W] -> int32 [W * 11]."""
    words = w.shape[0]
    bw = min(block_w, words)
    pad = (-words) % bw
    w2 = jnp.pad(w, (0, pad))
    out = pl.pallas_call(
        _unpack_kernel,
        grid=((words + pad) // bw,),
        in_specs=[pl.BlockSpec((bw,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bw, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((words + pad, BLOCK), jnp.int32),
        interpret=True,
    )(w2)
    return out[:words].reshape(-1)
