"""Pure-jnp oracles for every Pallas kernel and for the Rust hot path.

These definitions are the single source of truth for the numerics.  The
Pallas kernels (qkv_proj / quant_kv / fused_attn / pack3) are pytest-checked
against them, and ``aot.py`` exports golden vectors from them that the Rust
implementation (`rust/src/quant`, `rust/src/attention`) must match.

Quantization follows the paper exactly (Methodology, "Group-Wise Low-Bit
Quantization"):

    s = (max - min) / q_max
    q = clip(round((x - min) / s), 0, q_max)        # round = floor(u + 0.5)
    x~ = q * s + min

Rounding is floor(u + 0.5) — *not* banker's rounding — so that the Rust
side (`(u + 0.5).floor()`) is bit-identical.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

EPS = 1e-6


# ---------------------------------------------------------------------------
# Basic model ops
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm over the last dim."""
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * (1.0 / jnp.sqrt(ms + EPS)) * w).astype(x.dtype)


def rope(x: jnp.ndarray, pos: jnp.ndarray, base: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x: [..., T, H, hd] (hd even), pos: [T] (or [...,T])."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[..., :, None, None] * freqs  # [...,T,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * (1.0 / (1.0 + jnp.exp(-x)))


# ---------------------------------------------------------------------------
# Group-wise asymmetric quantization (paper §Asymmetric Low-Bit Quantization)
# ---------------------------------------------------------------------------
def _round_half_up(u: jnp.ndarray) -> jnp.ndarray:
    return jnp.floor(u + 0.5)


def quant_params(x: jnp.ndarray, qmax: int, axis: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(scale, min) per group; ``x`` already reshaped so ``axis`` is the group."""
    mn = jnp.min(x, axis=axis, keepdims=True)
    mx = jnp.max(x, axis=axis, keepdims=True)
    s = (mx - mn) / float(qmax)
    s = jnp.where(s < EPS, 1.0, s)
    return s, mn


def quantize(x: jnp.ndarray, s: jnp.ndarray, mn: jnp.ndarray, qmax: int) -> jnp.ndarray:
    q = _round_half_up((x - mn) / s)
    return jnp.clip(q, 0.0, float(qmax)).astype(jnp.int32)


def dequantize(q: jnp.ndarray, s: jnp.ndarray, mn: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * s + mn


def fake_quant(x: jnp.ndarray, bits: int, axis: int) -> jnp.ndarray:
    """quantize -> dequantize along ``axis`` groups (whole axis = one group)."""
    qmax = (1 << bits) - 1
    s, mn = quant_params(x, qmax, axis)
    return dequantize(quantize(x, s, mn, qmax), s, mn)


def fake_quant_key_per_channel(k: jnp.ndarray, bits: int, group: int = 32) -> jnp.ndarray:
    """Key cache quantization: groups of ``group`` consecutive *tokens* per
    channel.  k: [T, Hkv, hd], T divisible by ``group``."""
    t, h, d = k.shape
    assert t % group == 0, (t, group)
    kg = k.reshape(t // group, group, h, d)
    return fake_quant(kg, bits, axis=1).reshape(t, h, d)


def fake_quant_value_per_token(v: jnp.ndarray, bits: int, group: int = 32) -> jnp.ndarray:
    """Value cache quantization: groups of ``group`` consecutive *channels*
    per token.  v: [T, Hkv, hd], hd divisible by ``group``."""
    t, h, d = v.shape
    assert d % group == 0, (d, group)
    vg = v.reshape(t, h, d // group, group)
    return fake_quant(vg, bits, axis=3).reshape(t, h, d)


# ---------------------------------------------------------------------------
# 3-bit packing: 11 elements per u32 (10 x 3-bit + 1 x 2-bit), paper Eq. 12
# ---------------------------------------------------------------------------
PACK3_BLOCK = 11


def pack3(q: np.ndarray) -> np.ndarray:
    """q: int array, len divisible by 11, values already clipped per Eq. 12
    (q[i] <= 7 for i%11 < 10, q[i] <= 3 for i%11 == 10). Returns uint32."""
    q = np.asarray(q, dtype=np.uint32).reshape(-1, PACK3_BLOCK)
    out = np.zeros(q.shape[0], dtype=np.uint32)
    for i in range(10):
        out |= (q[:, i] & 0x7) << np.uint32(3 * i)
    out |= (q[:, 10] & 0x3) << np.uint32(30)
    return out


def unpack3(w: np.ndarray) -> np.ndarray:
    w = np.asarray(w, dtype=np.uint32)
    out = np.zeros((w.shape[0], PACK3_BLOCK), dtype=np.int32)
    for i in range(10):
        out[:, i] = (w >> np.uint32(3 * i)) & 0x7
    out[:, 10] = (w >> np.uint32(30)) & 0x3
    return out.reshape(-1)


def pack_uniform(q: np.ndarray, bits: int) -> np.ndarray:
    """Uniform packing for 1/2/4(/8)-bit: 32/bits elements per u32."""
    per = 32 // bits
    q = np.asarray(q, dtype=np.uint32).reshape(-1, per)
    out = np.zeros(q.shape[0], dtype=np.uint32)
    mask = np.uint32((1 << bits) - 1)
    for i in range(per):
        out |= (q[:, i] & mask) << np.uint32(bits * i)
    return out


def unpack_uniform(w: np.ndarray, bits: int) -> np.ndarray:
    per = 32 // bits
    w = np.asarray(w, dtype=np.uint32)
    mask = np.uint32((1 << bits) - 1)
    out = np.zeros((w.shape[0], per), dtype=np.int32)
    for i in range(per):
        out[:, i] = (w >> np.uint32(bits * i)) & mask
    return out.reshape(-1)


def fake_quant_3bit_blockwise(x: jnp.ndarray) -> jnp.ndarray:
    """Eq.12 fidelity oracle: within each 11-element block (along the group
    axis) element 10 only gets 2 bits.  x: [..., G] with G % 11 == 0; the
    group statistics are still over the whole last axis."""
    g = x.shape[-1]
    assert g % PACK3_BLOCK == 0
    s, mn = quant_params(x, 7, axis=-1)
    idx = jnp.arange(g) % PACK3_BLOCK
    qmax = jnp.where(idx == 10, 3.0, 7.0)
    q = jnp.clip(_round_half_up((x - mn) / s), 0.0, qmax)
    return q * s + mn


# ---------------------------------------------------------------------------
# Reference attention over a mixed cache (RPC window + quantized history)
# ---------------------------------------------------------------------------
def attn_mixed_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   boundary: int, k_bits: int, v_bits: int,
                   group: int = 32) -> jnp.ndarray:
    """Decode-step attention for one query over a cache of T tokens whose
    first ``boundary`` tokens are fake-quantized (per-channel K / per-token
    V) and the remainder (the RPC window) is full precision.

    q: [H, hd], k/v: [T, Hkv, hd] with H % Hkv == 0. Returns [H, hd].
    ``boundary`` must be a multiple of ``group``.
    """
    t, hkv, hd = k.shape
    h = q.shape[0]
    rep = h // hkv
    if boundary > 0:
        kq = fake_quant_key_per_channel(k[:boundary], k_bits, group)
        vq = fake_quant_value_per_token(v[:boundary], v_bits, group)
        k = jnp.concatenate([kq, k[boundary:]], axis=0)
        v = jnp.concatenate([vq, v[boundary:]], axis=0)
    kk = jnp.repeat(k, rep, axis=1)            # [T, H, hd]
    vv = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("hd,thd->ht", q, kk) / np.sqrt(hd)
    p = jnp.exp(scores - jnp.max(scores, axis=1, keepdims=True))
    p = p / jnp.sum(p, axis=1, keepdims=True)
    return jnp.einsum("ht,thd->hd", p, vv)
