"""Fused RMSNorm + QKV projection + RoPE as a Pallas kernel.

This is the L1 kernel that lowers into the AOT artifacts: the L2 model's
``decode_pre`` / ``prefill_pre`` graphs call :func:`qkv_proj` so the Pallas
lowering (interpret=True -> plain HLO) ends up inside the executables the
Rust runtime loads.

TPU mapping (DESIGN.md §Hardware-Adaptation): the token axis is the grid,
each program instance holds one token tile of the hidden states plus the
full projection weights in VMEM (for the reproduction model D=256 this is
~0.9 MB, far under the 16 MB VMEM budget; the analytic scaling table lives
in the bench output of rust/benches/quant_kernels.rs).  The three projections ride the MXU back-to-back
from the same normalized activation tile, which is the fusion the paper
implements with a CUDA kernel over shared memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROPE_BASE = 10000.0
EPS = 1e-6


def _rope_block(x: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """x: [BT, H, hd], pos: [BT] -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (ROPE_BASE ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _kernel(x_ref, pos_ref, lnw_ref, wq_ref, wk_ref, wv_ref,
            q_ref, k_ref, v_ref, *, n_heads: int, n_kv_heads: int, head_dim: int):
    x = x_ref[...].astype(jnp.float32)                       # [BT, D]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    xn = x * (1.0 / jnp.sqrt(ms + EPS)) * lnw_ref[...]
    pos = pos_ref[...]
    bt = x.shape[0]
    q = (xn @ wq_ref[...]).reshape(bt, n_heads, head_dim)
    k = (xn @ wk_ref[...]).reshape(bt, n_kv_heads, head_dim)
    v = xn @ wv_ref[...]
    q_ref[...] = _rope_block(q, pos).reshape(bt, n_heads * head_dim)
    k_ref[...] = _rope_block(k, pos).reshape(bt, n_kv_heads * head_dim)
    v_ref[...] = v


@functools.partial(jax.jit, static_argnames=("n_heads", "n_kv_heads", "head_dim", "block_t"))
def qkv_proj(x: jnp.ndarray, pos: jnp.ndarray, lnw: jnp.ndarray,
             wq: jnp.ndarray, wk: jnp.ndarray, wv: jnp.ndarray,
             *, n_heads: int, n_kv_heads: int, head_dim: int,
             block_t: int = 32) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [T, D], pos: [T] int32; w*: [D, heads*hd].

    Returns (q[T,H,hd], k[T,Hkv,hd], v[T,Hkv,hd]) with RoPE applied to q, k.
    T must be divisible by ``block_t`` (callers pad to the bucket size).
    """
    t, d = x.shape
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    grid = (t // bt,)
    qd, kd = n_heads * head_dim, n_kv_heads * head_dim
    out_shapes = (
        jax.ShapeDtypeStruct((t, qd), jnp.float32),
        jax.ShapeDtypeStruct((t, kd), jnp.float32),
        jax.ShapeDtypeStruct((t, kd), jnp.float32),
    )
    q, k, v = pl.pallas_call(
        functools.partial(_kernel, n_heads=n_heads, n_kv_heads=n_kv_heads,
                          head_dim=head_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, qd), lambda i: (0, 0)),
            pl.BlockSpec((d, kd), lambda i: (0, 0)),
            pl.BlockSpec((d, kd), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bt, qd), lambda i: (i, 0)),
            pl.BlockSpec((bt, kd), lambda i: (i, 0)),
            pl.BlockSpec((bt, kd), lambda i: (i, 0)),
        ),
        out_shape=out_shapes,
        interpret=True,
    )(x, pos, lnw, wq, wk, wv)
    return (q.reshape(t, n_heads, head_dim),
            k.reshape(t, n_kv_heads, head_dim),
            v.reshape(t, n_kv_heads, head_dim))
