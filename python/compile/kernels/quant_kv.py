"""Pallas kernels for the paper's asymmetric group-wise KV quantization.

Two fake-quant (quantize -> dequantize) kernels matching ref.py's oracles:

  * Key   — per-channel groups: ``group`` consecutive tokens of one channel
            share (scale, min).  Grid over token-groups.
  * Value — per-token groups: ``group`` consecutive channels of one token
            share (scale, min).  Grid over token tiles.

The real packed-int storage lives on the Rust side (`rust/src/quant`); these
kernels are used by the L2 eval/ablation graphs and are the numerics
contract both sides are tested against.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6


def _fq(x: jnp.ndarray, qmax: float, axis: int) -> jnp.ndarray:
    mn = jnp.min(x, axis=axis, keepdims=True)
    mx = jnp.max(x, axis=axis, keepdims=True)
    s = (mx - mn) / qmax
    s = jnp.where(s < EPS, 1.0, s)
    q = jnp.clip(jnp.floor((x - mn) / s + 0.5), 0.0, qmax)
    return q * s + mn


def _key_kernel(k_ref, o_ref, *, qmax: float):
    # block: [group, C] — one token-group across all channels; stats over axis 0
    o_ref[...] = _fq(k_ref[...], qmax, axis=0)


def _value_kernel(v_ref, o_ref, *, qmax: float, group: int):
    # block: [BT, C] with C % group == 0; stats over channel groups
    v = v_ref[...]
    bt, c = v.shape
    vg = v.reshape(bt, c // group, group)
    o_ref[...] = _fq(vg, qmax, axis=2).reshape(bt, c)


@functools.partial(jax.jit, static_argnames=("bits", "group"))
def fq_key_per_channel(k: jnp.ndarray, *, bits: int, group: int = 32) -> jnp.ndarray:
    """k: [T, Hkv, hd], T % group == 0.  Returns fake-quantized k."""
    t, h, d = k.shape
    assert t % group == 0
    qmax = float((1 << bits) - 1)
    k2 = k.reshape(t, h * d)
    out = pl.pallas_call(
        functools.partial(_key_kernel, qmax=qmax),
        grid=(t // group,),
        in_specs=[pl.BlockSpec((group, h * d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((group, h * d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h * d), jnp.float32),
        interpret=True,
    )(k2)
    return out.reshape(t, h, d)


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_t"))
def fq_value_per_token(v: jnp.ndarray, *, bits: int, group: int = 32,
                       block_t: int = 32) -> jnp.ndarray:
    """v: [T, Hkv, hd], hd % group == 0.  Returns fake-quantized v."""
    t, h, d = v.shape
    assert d % group == 0
    qmax = float((1 << bits) - 1)
    bt = min(block_t, t)
    assert t % bt == 0
    v2 = v.reshape(t, h * d)
    out = pl.pallas_call(
        functools.partial(_value_kernel, qmax=qmax, group=group),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, h * d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, h * d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h * d), jnp.float32),
        interpret=True,
    )(v2)
    return out.reshape(t, h, d)
