"""Fused mixed-precision decode attention as a Pallas kernel.

The paper's hot-spot: one query token attends over a cache whose *old*
region is quantized (per-channel K / per-token V) and whose recent region
(the RPC window) is full precision, with dequantization fused into the
score / weighted-value products instead of materializing a dequantized
cache (paper §CUDA Implementation ②).

TPU re-think of their CUDA kernel (DESIGN.md §Hardware-Adaptation): the
sequence axis is tiled into ``group``-token blocks streamed HBM->VMEM by
BlockSpec; each grid step fake-quantizes its K/V tile on the fly iff the
tile lies left of the runtime ``boundary`` scalar, then runs an online-
softmax update (flash-decoding) with the score/value contractions on the
MXU.  Scratch refs hold the running (max, denom, accumulator) so nothing
but the [H, hd] output ever leaves VMEM.

Runs interpret=True; the same python callable is used by the L2 eval
graphs and is pytest-checked against ref.attn_mixed_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPS = 1e-6
NEG_INF = -1e30


def _fq(x: jnp.ndarray, qmax: float, axis: int) -> jnp.ndarray:
    mn = jnp.min(x, axis=axis, keepdims=True)
    mx = jnp.max(x, axis=axis, keepdims=True)
    s = (mx - mn) / qmax
    s = jnp.where(s < EPS, 1.0, s)
    q = jnp.clip(jnp.floor((x - mn) / s + 0.5), 0.0, qmax)
    return q * s + mn


def _kernel(b_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, kq: float, vq: float, group: int, rep: int, scale: float,
            n_blocks: int):
    i = pl.program_id(0)
    q = q_ref[...]                                    # [H, hd]
    k = k_ref[...]                                    # [group, Hkv*hd]
    v = v_ref[...]
    h, hd = q.shape
    hkv = k.shape[1] // hd

    # Mixed-precision view of this tile: quantized iff fully left of boundary.
    boundary = b_ref[0]
    is_hist = (i + 1) * group <= boundary
    k_mix = jnp.where(is_hist, _fq(k, kq, axis=0), k)          # per-channel
    vg = v.reshape(group, hkv * hd // group, group)
    v_mix = jnp.where(is_hist, _fq(vg, vq, axis=2).reshape(group, hkv * hd), v)

    km = jnp.repeat(k_mix.reshape(group, hkv, hd), rep, axis=1)  # [g, H, hd]
    vm = jnp.repeat(v_mix.reshape(group, hkv, hd), rep, axis=1)

    s = jnp.einsum("hd,ghd->hg", q, km) * scale                # [H, group]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])                            # [H, group]
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_prev * alpha[:, None] + jnp.einsum("hg,ghd->hd", p, vm)
    m_ref[...] = m_cur

    @pl.when(i == n_blocks - 1)
    def _fin():
        o_ref[...] = acc_ref[...] / l_ref[...][:, None]


@functools.partial(jax.jit,
                   static_argnames=("k_bits", "v_bits", "group"))
def attn_mixed(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               boundary: jnp.ndarray, *, k_bits: int, v_bits: int,
               group: int = 32) -> jnp.ndarray:
    """q: [H, hd]; k, v: [T, Hkv, hd] (T % group == 0); boundary: i32 scalar
    array — tokens < boundary are treated as quantized history.

    Returns the attention output [H, hd].
    """
    t, hkv, hd = k.shape
    h = q.shape[0]
    assert t % group == 0 and h % hkv == 0 and (hkv * hd) % group == 0
    rep = h // hkv
    n_blocks = t // group
    kq = float((1 << k_bits) - 1)
    vq = float((1 << v_bits) - 1)
    b = jnp.asarray(boundary, dtype=jnp.int32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_kernel, kq=kq, vq=vq, group=group, rep=rep,
                          scale=1.0 / float(np.sqrt(hd)), n_blocks=n_blocks),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((h, hd), lambda i: (0, 0)),
            pl.BlockSpec((group, hkv * hd), lambda i: (i, 0)),
            pl.BlockSpec((group, hkv * hd), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((h, hd), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, hd), jnp.float32),
        ],
        interpret=True,
    )(b, q, k.reshape(t, hkv * hd), v.reshape(t, hkv * hd))
    return out
