"""AOT build: checkpoint -> artifacts/ (HLO text + weights + goldens).

Python runs ONCE here (``make artifacts``); the Rust binary is
self-contained afterwards.  Interchange is HLO *text* — xla_extension
0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction ids); the
text parser reassigns ids (see /opt/xla-example/README.md).

Outputs (see DESIGN.md §2):
  pre_t{N}.hlo.txt     RMSNorm+QKV+RoPE (Pallas) — decode rows or prefill
  post_t{N}.hlo.txt    out-proj + MLP
  logits_t{N}.hlo.txt  final norm + LM head
  profiler_grads.hlo.txt  loss + per-layer grad norms of W_k / W_v
  weights.bin + manifest.json   trained checkpoint, canonical order
  importance.json      profiler scores + default k/v bit plan
  goldens/*.json       parity vectors for the Rust tests
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, profiler
from .kernels import ref
from .model import (ModelConfig, flat_weights, forward_jnp, logits_graph,
                    post_graph, pre_graph, profiler_graph, unflatten)

BUCKETS = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
PROFILE_T = 160


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_executables(cfg: ModelConfig, out_dir: str) -> dict:
    d, qd, kd, ff, v = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff, cfg.vocab
    index: dict = {"pre": {}, "post": {}, "logits": {}}
    pre, post, logits = pre_graph(cfg), post_graph(cfg), logits_graph(cfg)
    for t in BUCKETS:
        lo = jax.jit(pre).lower(sds((t, d)), sds((t,), jnp.int32), sds((d,)),
                                sds((d, qd)), sds((d, kd)), sds((d, kd)))
        name = f"pre_t{t}.hlo.txt"
        open(os.path.join(out_dir, name), "w").write(to_hlo_text(lo))
        index["pre"][str(t)] = name

        lo = jax.jit(post).lower(sds((t, qd)), sds((t, d)), sds((qd, d)),
                                 sds((d,)), sds((d, ff)), sds((d, ff)),
                                 sds((ff, d)))
        name = f"post_t{t}.hlo.txt"
        open(os.path.join(out_dir, name), "w").write(to_hlo_text(lo))
        index["post"][str(t)] = name

        lo = jax.jit(logits).lower(sds((t, d)), sds((d,)), sds((d, v)))
        name = f"logits_t{t}.hlo.txt"
        open(os.path.join(out_dir, name), "w").write(to_hlo_text(lo))
        index["logits"][str(t)] = name
        print(f"  lowered bucket t={t}", flush=True)

    flat_shapes = [sds(a.shape) for _, a in flat_weights(cfg, init_like(cfg))]
    lo = jax.jit(profiler_graph(cfg)).lower(
        sds((1, PROFILE_T), jnp.int32), sds((1, PROFILE_T)), *flat_shapes)
    open(os.path.join(out_dir, "profiler_grads.hlo.txt"), "w").write(to_hlo_text(lo))
    index["profiler"] = {"file": "profiler_grads.hlo.txt", "seq_len": PROFILE_T}
    return index


def init_like(cfg: ModelConfig):
    from .model import init_params
    return init_params(cfg, 0)


def export_weights(cfg: ModelConfig, params, out_dir: str) -> list[dict]:
    entries = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name, arr in flat_weights(cfg, params):
            a = np.ascontiguousarray(arr, dtype=np.float32)
            f.write(a.tobytes())
            entries.append({"name": name, "shape": list(a.shape),
                            "offset": offset, "numel": int(a.size)})
            offset += a.nbytes
    return entries


# ---------------------------------------------------------------------------
# Goldens for rust parity tests
# ---------------------------------------------------------------------------
def write_goldens(cfg: ModelConfig, params, out_dir: str) -> None:
    gdir = os.path.join(out_dir, "goldens")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.RandomState(42)

    # 1. group quantization + packing vectors
    t, hkv, hd = 64, cfg.n_kv_heads, cfg.head_dim
    k = rng.randn(t, hkv, hd).astype(np.float32)
    v = rng.randn(t, hkv, hd).astype(np.float32)
    gq = {"t": t, "hkv": hkv, "hd": hd, "group": cfg.group,
          "k": k.ravel().tolist(), "v": v.ravel().tolist()}
    for bits in (1, 2, 3, 4):
        gq[f"k_fq_{bits}"] = np.asarray(
            ref.fake_quant_key_per_channel(jnp.asarray(k), bits, cfg.group)).ravel().tolist()
        gq[f"v_fq_{bits}"] = np.asarray(
            ref.fake_quant_value_per_token(jnp.asarray(v), bits, cfg.group)).ravel().tolist()
    qvals = rng.randint(0, 8, size=176)
    qvals[10::11] &= 0x3
    gq["pack3_q"] = qvals.tolist()
    gq["pack3_words"] = ref.pack3(qvals).astype(np.int64).tolist()
    x33 = rng.randn(4, 33).astype(np.float32)
    gq["fq3_block_in"] = x33.ravel().tolist()
    gq["fq3_block_out"] = np.asarray(
        ref.fake_quant_3bit_blockwise(jnp.asarray(x33))).ravel().tolist()
    json.dump(gq, open(os.path.join(gdir, "quant.json"), "w"))

    # 2. mixed attention vector
    h = cfg.n_heads
    q1 = rng.randn(h, hd).astype(np.float32)
    out = ref.attn_mixed_ref(jnp.asarray(q1), jnp.asarray(k), jnp.asarray(v),
                             boundary=32, k_bits=2, v_bits=2, group=cfg.group)
    json.dump({"h": h, "hd": hd, "t": t, "hkv": hkv, "boundary": 32,
               "k_bits": 2, "v_bits": 2,
               "q": q1.ravel().tolist(), "k": k.ravel().tolist(),
               "v": v.ravel().tolist(),
               "out": np.asarray(out).ravel().tolist()},
              open(os.path.join(gdir, "attn.json"), "w"))

    # 3. model forward goldens: logits for a fixed prompt (fp path)
    rng2 = np.random.RandomState(7)
    toks, _ = corpus.batch(rng2, 1, 32, task="lm")
    logits = np.asarray(forward_jnp(jax.tree_util.tree_map(jnp.asarray, params),
                                    jnp.asarray(toks), cfg))[0]
    greedy = np.argmax(logits, axis=-1)
    json.dump({"tokens": toks[0].tolist(),
               "logits_last": logits[-1].tolist(),
               "greedy": greedy.tolist()},
              open(os.path.join(gdir, "model.json"), "w"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--train-steps", type=int, default=700)
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    cfg = ModelConfig()

    ckpt = os.path.join(out_dir, "checkpoint.npz")
    if args.retrain or not os.path.exists(ckpt):
        from .train import train
        print("training checkpoint ...", flush=True)
        params, _ = train(cfg, steps=args.train_steps, batch_size=16,
                          seq_len=160,
                          log_path=os.path.join(out_dir, "train_log.json"))
        np.savez(ckpt, **dict(flat_weights(cfg, params)))
    data = np.load(ckpt)
    names = [n for n, _ in flat_weights(cfg, init_like(cfg))]
    params = unflatten(cfg, [np.asarray(data[n]) for n in names])

    print("exporting weights ...", flush=True)
    weight_entries = export_weights(cfg, params, out_dir)

    print("profiling importance ...", flush=True)
    t0 = time.time()
    jparams = jax.tree_util.tree_map(jnp.asarray, params)
    plan = profiler.profile(cfg, jparams, n_prompts=24, seq_len=PROFILE_T)
    profiler.save_importance(os.path.join(out_dir, "importance.json"), cfg,
                             plan, extra={"profile_seconds": time.time() - t0})
    print(f"  plan: {plan.name}  k_bits={plan.k_bits} v_bits={plan.v_bits}")

    print("lowering executables ...", flush=True)
    index = lower_executables(cfg, out_dir)

    print("writing goldens ...", flush=True)
    write_goldens(cfg, params, out_dir)

    manifest = {"model": cfg.to_dict(), "weights": weight_entries,
                "executables": index, "buckets": BUCKETS,
                "profile_seq_len": PROFILE_T}
    json.dump(manifest, open(os.path.join(out_dir, "manifest.json"), "w"),
              indent=1)
    open(os.path.join(out_dir, ".stamp"), "w").write(str(time.time()))
    print("artifacts complete:", out_dir)


if __name__ == "__main__":
    main()
