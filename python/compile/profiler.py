"""KVmix profiler (paper §KV Importance Analysis, Algorithm 1).

Computes the L2 norms of the loss gradients w.r.t. each layer's Key/Value
projection weights over a set of prompts, averages them (Eq. 11), ranks
layers, and emits the mixed-precision allocation: the top ``high_frac`` of
Key layers get ``k_high_bits`` (3), of Value layers ``v_high_bits`` (4),
everyone else ``low_bits`` (2); RPC ratios follow the paper's defaults
(20% for high-bit layers, 10% for low-bit).

This python implementation is the build-time reference; the same graph is
AOT-lowered (model.profiler_graph) so the Rust profiler
(rust/src/profiler) can reproduce the analysis through PJRT, and both are
cross-checked against ``importance.json``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, loss_fn


@dataclasses.dataclass
class QuantPlan:
    """Per-layer bit allocation + RPC ratios (the model's quant config)."""

    k_bits: list[int]
    v_bits: list[int]
    k_rpc: list[float]
    v_rpc: list[float]
    k_scores: list[float]
    v_scores: list[float]

    @property
    def avg_k_bits(self) -> float:
        return float(np.mean(self.k_bits))

    @property
    def avg_v_bits(self) -> float:
        return float(np.mean(self.v_bits))

    @property
    def name(self) -> str:
        return f"kvmix-k{self.avg_k_bits:.2f}v{self.avg_v_bits:.2f}"

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["avg_k_bits"] = self.avg_k_bits
        d["avg_v_bits"] = self.avg_v_bits
        d["name"] = self.name
        return d


def grad_norms(cfg: ModelConfig, params, prompts: np.ndarray,
               masks: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Average per-layer L2 gradient norms over P prompts (Eq. 10–11).

    prompts: [P, T] int32, masks: [P, T] f32. Returns (k_norms[L], v_norms[L]).
    """

    def loss_of_kv(kvs, tokens, mask):
        p2 = {**params, "layers": [
            {**lyr, "wk": kvs[i][0], "wv": kvs[i][1]}
            for i, lyr in enumerate(params["layers"])]}
        return loss_fn(p2, tokens, mask, cfg)

    kvs = [(lyr["wk"], lyr["wv"]) for lyr in params["layers"]]
    gfn = jax.jit(jax.grad(loss_of_kv))
    k_acc = np.zeros(cfg.n_layers)
    v_acc = np.zeros(cfg.n_layers)
    for p in range(prompts.shape[0]):
        g = gfn(kvs, jnp.asarray(prompts[p:p + 1]), jnp.asarray(masks[p:p + 1]))
        for i in range(cfg.n_layers):
            k_acc[i] += float(jnp.linalg.norm(g[i][0]))
            v_acc[i] += float(jnp.linalg.norm(g[i][1]))
    return k_acc / prompts.shape[0], v_acc / prompts.shape[0]


def allocate(k_scores: np.ndarray, v_scores: np.ndarray,
             high_frac: float = 0.2, k_high_bits: int = 3,
             v_high_bits: int = 4, low_bits: int = 2,
             rpc_high: float = 0.2, rpc_low: float = 0.1) -> QuantPlan:
    """Rank layers by importance; top ``high_frac`` get high bits (paper's
    20%-80% split, adjustable)."""
    n = len(k_scores)
    n_high = int(round(high_frac * n))
    k_top = set(np.argsort(-k_scores)[:n_high].tolist())
    v_top = set(np.argsort(-v_scores)[:n_high].tolist())
    k_bits = [k_high_bits if i in k_top else low_bits for i in range(n)]
    v_bits = [v_high_bits if i in v_top else low_bits for i in range(n)]
    k_rpc = [rpc_high if i in k_top else rpc_low for i in range(n)]
    v_rpc = [rpc_high if i in v_top else rpc_low for i in range(n)]
    return QuantPlan(k_bits, v_bits, k_rpc, v_rpc,
                     k_scores.tolist(), v_scores.tolist())


def profile(cfg: ModelConfig, params, n_prompts: int = 24, seq_len: int = 160,
            seed: int = 7, task: str | None = None,
            high_frac: float = 0.2) -> QuantPlan:
    rng = np.random.RandomState(seed)
    prompts, masks = corpus.batch(rng, n_prompts, seq_len, task=task)
    ks, vs = grad_norms(cfg, params, prompts, masks)
    return allocate(ks, vs, high_frac=high_frac)


def save_importance(path: str, cfg: ModelConfig, plan: QuantPlan,
                    extra: dict | None = None) -> None:
    doc = {"model": cfg.to_dict(), "plan": plan.to_dict()}
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
